"""Session API tests (ISSUE 1/2): backend registry, bound-function handles,
streaming fork-join, partial-failure policies, the paper-style shim, the
cross-backend contract matrix, and admission control."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import cloud
from repro.cloud import (Saturated, Session, as_completed,
                         available_backends, gather, register_backend,
                         resolve_backend)
from repro.core import FunctionConfig
from repro.dispatch import (Dispatcher, FaultPlan, HttpBackend,
                            InlineBackend, ProcessesBackend, SimAWSBackend,
                            WorkerPool, dispatch, wait)


# ------------------------------------------------------------- registry ----

def test_registry_resolution():
    for name, cls in (("threads", WorkerPool), ("inline", InlineBackend),
                      ("sim-aws", SimAWSBackend)):
        b = resolve_backend(name, os_threads=2)
        assert isinstance(b, cls)
        b.shutdown()
    assert {"threads", "inline", "sim-aws",
            "processes", "http"} <= set(available_backends())


def test_registry_unknown_name_lists_available():
    with pytest.raises(ValueError, match="threads"):
        resolve_backend("gcp-functions")


def test_registry_accepts_instances_and_factories():
    pool = WorkerPool(os_threads=1)
    assert resolve_backend(pool) is pool
    pool.shutdown()
    b = resolve_backend(InlineBackend)          # a class is a factory
    assert isinstance(b, InlineBackend)


def test_register_custom_backend():
    register_backend("test-inline-alias", InlineBackend)
    try:
        with Session("test-inline-alias") as sess:
            f = sess.function(lambda x: x + 1)
            assert float(f.submit(jnp.float32(1)).result()) == 2.0
    finally:
        from repro.dispatch.backends import _REGISTRY
        _REGISTRY.pop("test-inline-alias")


def test_capability_flags():
    assert WorkerPool.capabilities.concurrent
    assert not InlineBackend.capabilities.concurrent
    assert SimAWSBackend.capabilities.models_latency
    assert not WorkerPool.capabilities.models_latency
    assert ProcessesBackend.capabilities.cross_process
    assert HttpBackend.capabilities.measures_latency
    assert not WorkerPool.capabilities.cross_process
    assert not SimAWSBackend.capabilities.measures_latency


# ------------------------------------------------------ session basics ----

def test_inline_backend_is_zero_thread_and_synchronous():
    with Session("inline") as sess:
        assert len(sess.backend._threads) == 0
        fut = sess.function(lambda x: x * 2).submit(jnp.float32(3))
        assert fut.done()                       # resolved during submit
        assert float(fut.result()) == 6.0


def test_same_code_runs_on_every_backend():
    """The acceptance property: no per-backend application-code changes."""
    def flow(backend):
        with Session(backend, os_threads=4) as sess:
            f = sess.function(lambda x: jnp.sum(x * x), name="ssq")
            return [float(r) for r in f.map([(jnp.ones(4) * i,)
                                             for i in range(4)])]

    results = {b: flow(b) for b in ("threads", "inline", "sim-aws")}
    assert results["threads"] == results["inline"] == results["sim-aws"] \
        == [0.0, 4.0, 16.0, 36.0]


def test_session_owns_cost_accounting():
    with Session("inline") as sess:
        f = sess.function(lambda x: x + 1)
        f.map([(jnp.float32(i),) for i in range(5)])
        assert sess.cost.invocations == 5
        assert sess.cost.gb_seconds > 0
        assert len(sess.records) == 5


def test_accounting_complete_when_map_returns():
    """map()/gather() join on futures, not wait(): cost and records must be
    fully recorded by the time the join returns (claim→record→resolve)."""
    with Session("threads", os_threads=4) as sess:
        f = sess.function(lambda x: x, jax_traceable=False)
        for i in range(200):
            before = sess.cost.invocations
            f.map([(j,) for j in range(4)])
            assert sess.cost.invocations == before + 4
            assert len(sess.records) == before + 4


def test_local_call_is_untouched():
    with Session("inline") as sess:
        calls = []

        def tracked(x):
            calls.append(x)
            return x

        f = sess.function(tracked, jax_traceable=False)
        assert f(7) == 7                        # plain local execution
        assert calls == [7]
        assert sess.cost.invocations == 0       # nothing billed


# ------------------------------------------------- options precedence ----

def test_options_override_precedence():
    """call (.options) > handle (session.function kwargs) > function config."""
    from repro.core import RemoteFunction
    rf = RemoteFunction(lambda x: x + 1,
                        config=FunctionConfig(memory_mb=1024))
    with Session("inline") as sess:
        handle = sess.function(rf, memory_mb=512)
        assert handle.config.memory_mb == 512           # handle beats function
        call = handle.options(memory_mb=256)
        assert call.config.memory_mb == 256             # call beats handle
        assert handle.config.memory_mb == 512           # chaining is pure
        rec = call.submit(jnp.float32(0)).record
        assert rec.memory_gb == 0.25                    # override reached bill
        rec2 = handle.submit(jnp.float32(0)).record
        assert rec2.memory_gb == 0.5


def test_options_rejects_unknown_fields():
    with Session("inline") as sess:
        f = sess.function(lambda x: x)
        with pytest.raises(TypeError, match="vcpus"):
            f.options(vcpus=4)


def test_policy_options_do_not_redeploy():
    """timeout/retries/hedging are client policy: overriding them must hit
    the deploy cache; artifact/billing fields (memory) must not."""
    with Session("inline") as sess:
        f = sess.function(lambda x: x + 1)
        f.submit(jnp.float32(0)).result()
        assert sess.deployment.compile_count == 1
        f.options(timeout_s=60, max_retries=9).submit(jnp.float32(0)).result()
        assert sess.deployment.compile_count == 1       # cache hit
        assert sess.deployment.cache_hits >= 1
        f.options(memory_mb=2048).submit(jnp.float32(0)).result()
        assert sess.deployment.compile_count == 2       # new entry point


def test_options_serializer_changes_wire_format():
    with Session("inline") as sess:
        f = sess.function(lambda x: x + 1)
        rec_b = f.options(serializer="binary").submit(jnp.float32(1)).record
        rec_j = f.options(serializer="structured_json") \
            .submit(jnp.float32(1)).record
        assert rec_j.payload_bytes > rec_b.payload_bytes   # JSON tax


# ------------------------------------------------ streaming fork-join ----

def test_map_unordered_yields_in_completion_order():
    with Session("threads", os_threads=4) as sess:
        def task(s):
            time.sleep(s)
            return s

        f = sess.function(task, jax_traceable=False)
        seen = list(f.map_unordered([0.4, 0.01, 0.15]))
        assert sorted(seen) == [0.01, 0.15, 0.4]
        assert seen[0] == 0.01                  # fastest first, not submit order
        assert seen != [0.4, 0.01, 0.15]


def test_as_completed_streams_futures():
    with Session("threads", os_threads=4) as sess:
        def task(s):
            time.sleep(s)
            return s

        f = sess.function(task, jax_traceable=False)
        futs = [f.submit(s) for s in (0.3, 0.01)]
        first = next(as_completed(futs))
        assert first.result() == 0.01
        gather(futs)


def test_gather_raise_policy():
    with Session("inline") as sess:
        def picky(x):
            if x == 2:
                raise ValueError("bad input 2")
            return x

        f = sess.function(picky, jax_traceable=False)
        futs = [f.submit(i) for i in range(4)]
        with pytest.raises(ValueError, match="bad input 2"):
            gather(futs)


def test_gather_batch_timeout_raises_even_with_return_exceptions():
    """An unfinished task is not a settled failure: the batch deadline
    raises instead of planting TimeoutError in a result slot."""
    with Session("threads", os_threads=2) as sess:
        def slow(s):
            time.sleep(s)
            return s

        f = sess.function(slow, jax_traceable=False)
        futs = [f.submit(0.01), f.submit(2.0)]
        with pytest.raises(TimeoutError):
            gather(futs, return_exceptions=True, timeout=0.3)
        gather(futs)                       # settle before session close


def test_function_rejects_rebinding_kwargs_on_remote_function():
    from repro.core import RemoteFunction
    rf = RemoteFunction(lambda x: x)
    with Session("inline") as sess:
        with pytest.raises(TypeError, match="RemoteFunction"):
            sess.function(rf, name="other")


def test_gather_return_exceptions_policy():
    with Session("inline") as sess:
        def picky(x):
            if x % 2:
                raise ValueError(f"odd {x}")
            return x

        f = sess.function(picky, jax_traceable=False)
        out = gather([f.submit(i) for i in range(4)], return_exceptions=True)
        assert out[0] == 0 and out[2] == 2
        assert isinstance(out[1], ValueError)
        assert isinstance(out[3], ValueError)


# ----------------------------------------- sim-aws: faults + hedging ----

def test_sim_aws_retry_and_hedging_interplay():
    """Crashes are retried and stragglers hedged on the same run; results
    stay exact and every record carries a modeled client latency."""
    with Session("sim-aws", os_threads=8,
                 fault_plan=FaultPlan(failure_rate=0.15, straggler_rate=0.2,
                                      straggler_sleep_s=0.3, seed=11)) as sess:
        f = sess.function(lambda x: x * 2, memory_mb=512, max_retries=8)
        out = f.map([(jnp.float32(i),) for i in range(12)],
                    hedge_quantile=0.5)
        assert [float(o) for o in out] == [2.0 * i for i in range(12)]
        assert sum(r.attempts for r in sess.records) >= 12
        assert all(r.modeled_latency_ms > 0 for r in sess.records)
        # cold starts show up as a modeled penalty, not just a flag
        cold = [r for r in sess.records if r.cold_start]
        warm = [r for r in sess.records if not r.cold_start]
        if cold and warm:
            assert (min(c.modeled_latency_ms for c in cold)
                    > min(w.modeled_latency_ms for w in warm))


def test_sim_aws_inflight_counter_survives_hedging():
    with Session("sim-aws", os_threads=4,
                 fault_plan=FaultPlan(straggler_rate=0.3,
                                      straggler_sleep_s=0.2, seed=3)) as sess:
        f = sess.function(lambda x: x + 1)
        f.map([(jnp.float32(i),) for i in range(8)], hedge_quantile=0.5)
        f.map([(jnp.float32(i),) for i in range(8)])
        assert sess.backend._inflight == 0      # every submit was balanced


# -------------------------------------------------- paper-style shim ----

def test_paper_shim_accepts_session():
    """cppless::dispatch/wait still work, with a Session as the namespace."""
    with Session("threads", os_threads=4) as sess:
        cfg = FunctionConfig(memory_mb=512)
        futs = [dispatch(sess, lambda x: x * 3, jnp.float32(i), config=cfg)
                for i in range(6)]
        wait(sess)
        assert sorted(float(f.result()) for f in futs) == \
            [3.0 * i for i in range(6)]
        assert sess.cost.invocations == 6


def test_shim_and_session_flows_are_equivalent():
    def flow_shim():
        d = Dispatcher(os_threads=2)
        try:
            inst = d.create_instance()
            futs = [dispatch(inst, lambda x: x + 10, jnp.float32(i))
                    for i in range(5)]
            wait(inst)
            return [float(f.result()) for f in futs]
        finally:
            d.shutdown()

    def flow_session():
        with Session("threads", os_threads=2) as sess:
            f = sess.function(lambda x: x + 10)
            return [float(r) for r in f.map([(jnp.float32(i),)
                                             for i in range(5)])]

    assert flow_shim() == flow_session() == [10.0 + i for i in range(5)]


def test_session_wraps_caller_owned_dispatcher():
    d = Dispatcher(os_threads=2)
    try:
        with Session.from_dispatcher(d) as sess:
            f = sess.function(lambda x: x + 1)
            assert float(f.submit(jnp.float32(1)).result()) == 2.0
        # exiting the session must NOT shut down the caller's dispatcher
        inst = d.create_instance()
        assert float(inst.dispatch(lambda x: x, jnp.float32(5))
                     .result(timeout=30)) == 5.0
    finally:
        d.shutdown()


def test_cloud_namespace_exports():
    for name in ("Session", "BoundFunction", "gather", "as_completed",
                 "register_backend", "resolve_backend", "available_backends",
                 "Saturated"):
        assert hasattr(cloud, name)


# ------------------------------------------------ backend contract matrix ---
# One suite, every registered backend (ISSUE 2 satellite): the Backend
# contract is enforced by a single matrix instead of per-backend tests.
# `processes` and `http` run the same tasks in real worker processes, so
# the task functions live at module level (shippable by reference).

MATRIX_BACKENDS = ("inline", "threads", "sim-aws", "processes", "http",
                   "http-aio")


def matrix_square_sum(x):
    import jax.numpy as jnp
    return jnp.sum(x * x)


def matrix_picky(x):
    if x == 2:
        raise ValueError("bad input 2")
    return x


@pytest.fixture(scope="module", params=MATRIX_BACKENDS)
def any_backend(request):
    with Session(request.param, os_threads=2) as sess:
        yield sess


def test_matrix_submit_resolves_with_billing(any_backend):
    f = any_backend.function(matrix_square_sum, name="mat_ssq",
                             memory_mb=512)
    before = any_backend.cost.invocations
    fut = f.submit(jnp.ones(4))
    assert float(fut.result(timeout=300)) == 4.0
    rec = fut.record
    assert rec is not None and rec.memory_gb == 0.5
    assert rec.worker_id > 0
    assert any_backend.cost.invocations == before + 1


def test_matrix_map_is_ordered(any_backend):
    f = any_backend.function(matrix_square_sum, name="mat_ssq")
    out = [float(r) for r in f.map([(jnp.ones(4) * i,) for i in range(4)])]
    assert out == [0.0, 4.0, 16.0, 36.0]


def test_matrix_map_unordered_yields_all(any_backend):
    f = any_backend.function(matrix_square_sum, name="mat_ssq")
    seen = sorted(float(r) for r in
                  f.map_unordered([(jnp.ones(4) * i,) for i in range(4)]))
    assert seen == [0.0, 4.0, 16.0, 36.0]


def test_matrix_gather_policies(any_backend):
    f = any_backend.function(matrix_picky, jax_traceable=False)
    futs = [f.submit(i) for i in range(4)]
    out = gather(futs, return_exceptions=True, timeout=300)
    assert out[0] == 0 and out[1] == 1 and out[3] == 3
    assert isinstance(out[2], ValueError)         # type survives the wire
    futs2 = [f.submit(i) for i in range(4)]
    with pytest.raises(ValueError, match="bad input 2"):
        gather(futs2, timeout=300)


def test_matrix_options_override_reaches_bill(any_backend):
    f = any_backend.function(matrix_square_sum, name="mat_ssq")
    fut = f.options(memory_mb=2048).submit(jnp.ones(2))
    fut.result(timeout=300)
    assert fut.record.memory_gb == 2.0            # redeploy honored remotely
    fut2 = f.submit(jnp.ones(2))
    fut2.result(timeout=300)
    assert fut2.record.memory_gb == 1.0


def test_matrix_warm_reuse_accounting(any_backend):
    f = any_backend.function(matrix_square_sum, name="mat_warm")
    before = len(any_backend.records)
    f.map([(jnp.ones(2),)] * 6)
    recs = any_backend.records[before:before + 6]
    assert sum(1 for r in recs if r.cold_start) < 6   # warm reuse happened


# ------------------------------------------------------ admission control ---

def test_session_exposes_inflight_and_queue_depth():
    with Session("threads", os_threads=1) as sess:
        assert sess.inflight == 0 and sess.queue_depth == 0

        def slow(s):
            time.sleep(s)
            return s

        f = sess.function(slow, jax_traceable=False)
        futs = [f.submit(0.3) for _ in range(3)]
        assert sess.inflight == 3         # one running + queued behind it
        gather(futs)
        assert sess.inflight == 0


def test_shed_raises_saturated_instead_of_queueing():
    with Session("threads", os_threads=1, max_concurrency=2,
                 shed=True) as sess:
        def slow(s):
            time.sleep(s)
            return s

        f = sess.function(slow, jax_traceable=False)
        futs = [f.submit(0.5), f.submit(0.5)]
        with pytest.raises(Saturated, match="max_concurrency=2"):
            f.submit(0.5)
        # map-sized admission is checked up front, before any dispatch
        with pytest.raises(Saturated):
            f.map([(0.1,)] * 3)
        gather(futs)
        assert float(f.submit(0.01).result(timeout=30)) == 0.01  # recovered


def test_shed_map_failure_keeps_sibling_reservations():
    """A failed task must free only ITS admission slot — siblings still in
    flight keep theirs, so a follow-up burst is correctly shed."""
    with Session("threads", os_threads=2, max_concurrency=2,
                 shed=True) as sess:
        def task(s):
            if s < 0:
                raise ValueError("boom")
            time.sleep(s)
            return s

        f = sess.function(task, jax_traceable=False)
        with pytest.raises(ValueError, match="boom"):
            f.map([(-1,), (0.6,)])
        with pytest.raises(Saturated):     # the sibling still holds a slot
            f.map([(0.01,), (0.01,)])
        sess.wait()                        # sibling resolves → slots free
        assert f.map([(0.01,), (0.01,)]) == [0.01, 0.01]


def matrix_sleepy(s):
    import time
    time.sleep(s)
    return s


@pytest.mark.parametrize("backend", ["processes", "http", "http-aio"])
def test_shed_saturated_and_recovers_on_real_transports(backend):
    """Backpressure under real transports (ISSUE 3 satellite): shed=True
    raises Saturated at the limit, and admission slots release when the
    remote invocations complete — the recovery half of the contract."""
    with Session(backend, os_threads=2, max_concurrency=2,
                 shed=True) as sess:
        f = sess.function(matrix_sleepy, jax_traceable=False)
        futs = [f.submit(0.5), f.submit(0.5)]
        with pytest.raises(Saturated, match="max_concurrency=2"):
            f.submit(0.5)
        gather(futs, timeout=300)
        # slots released by completion → the session admits again
        assert f.submit(0.01).result(timeout=300) == 0.01


def test_shed_off_keeps_queueing_semantics():
    with Session("threads", os_threads=1, max_concurrency=1) as sess:
        def slow(s):
            time.sleep(s)
            return s

        f = sess.function(slow, jax_traceable=False)
        futs = [f.submit(0.05) for _ in range(3)]   # over the limit: queued
        assert [r for r in gather(futs)] == [0.05] * 3
