"""Transport-agnostic worker runtime (ISSUE 2): wire protocol, code
shipping, the worker host, and the real `processes`/`http` backends —
including the dead-worker and wire-deserialize error paths."""
import os
import threading
import time

import jax.numpy as jnp
import pytest

from repro.cloud import Session
from repro.core import Deployment, freeze_function, thaw_function
from repro.core.codeship import CodeShipError
from repro.dispatch import HttpBackend, WorkerCrash
from repro.runtime.sandbox import FaultPlan, SandboxHost
from repro.runtime.worker_host import WorkerHost, serve_http
from repro.serialization import serialize, wire


# Module-level task functions: shippable to worker processes by reference
# (the test module rides to workers on the propagated import path).

def task_square_sum(x):
    import jax.numpy as jnp
    return jnp.sum(x * x)


def task_raise(x):
    raise ValueError(f"bad input {x}")


def task_hard_exit(x):
    os._exit(13)               # sandbox loss: no goodbye on the wire


def task_base_exception(x):
    raise SystemExit(3)        # escapes the handler: retryable + traceback


# ------------------------------------------------------------------ wire ----

def test_wire_invoke_roundtrip():
    frame = wire.encode_invoke("fn_abc", b"\x00payload", task_id=7, attempt=2)
    msg = wire.decode(frame)
    assert isinstance(msg, wire.InvokeRequest)
    assert (msg.function, msg.payload, msg.task_id, msg.attempt) == \
        ("fn_abc", b"\x00payload", 7, 2)


def test_wire_result_roundtrip():
    frame = wire.encode_result(b"blob", stats={"compute_s": 0.5},
                               server_s=0.7, cold_start=True, worker_id=42)
    msg = wire.decode(frame)
    assert isinstance(msg, wire.ResultReply)
    assert msg.blob == b"blob" and msg.worker_id == 42 and msg.cold_start
    assert msg.stats["compute_s"] == 0.5 and msg.server_s == 0.7


def test_wire_error_roundtrip_and_reconstruction():
    try:
        raise ValueError("kaboom")
    except ValueError as e:
        frame = wire.encode_error(e, traceback_text="Traceback ... kaboom")
    msg = wire.decode(frame)
    assert isinstance(msg, wire.ErrorReply) and not msg.retryable
    exc = wire.to_exception(msg)
    assert isinstance(exc, ValueError) and str(exc) == "kaboom"
    assert "kaboom" in exc.remote_traceback


def test_wire_unknown_exception_type_becomes_remote_task_error():
    msg = wire.decode(wire.encode_error(etype="WeirdCustomError",
                                        message="m", retryable=False))
    exc = wire.to_exception(msg)
    assert isinstance(exc, wire.RemoteTaskError)
    assert "WeirdCustomError" in str(exc)


def test_wire_malformed_frames_raise():
    good = wire.encode_invoke("f", b"x")
    for bad in (b"", b"shrt", b"XXXX" + good[4:],           # magic
                good[:4] + b"\xff\xff" + good[6:],          # version
                good[:11],                                  # truncated header
                good[:6] + bytes([99]) + good[7:]):         # unknown kind
        with pytest.raises(wire.WireProtocolError):
            wire.decode(bad)


# -------------------------------------------------------------- codeship ----

def test_freeze_importable_function_ships_by_reference():
    frozen = freeze_function(task_square_sum)
    assert frozen["kind"] == "ref"
    assert thaw_function(frozen) is task_square_sum


def test_freeze_closure_ships_code_with_payload_slots():
    scale = 3.0                      # data capture: travels in payloads
    fn = lambda x: scale * x         # noqa: E731
    frozen = freeze_function(fn)
    assert frozen["kind"] == "code"
    assert frozen["freevars"] == {"scale": None}
    thawed = thaw_function(frozen)
    from repro.core import rebind
    assert rebind(thawed, {"scale": 5.0})(2.0) == 10.0


def test_freeze_callable_capture_travels_with_artifact():
    def helper(x):
        return x + 1

    fn = lambda x: helper(x) * 2     # noqa: E731
    thawed = thaw_function(freeze_function(fn))
    assert thawed(3) == 8            # helper code came along


def test_freeze_main_module_gets_fresh_globals():
    def script_fn(x):
        import math
        return math.sqrt(x)

    script_fn.__module__ = "__main__"
    script_fn.__qualname__ = "script_fn"
    thawed = thaw_function(freeze_function(script_fn))
    assert thawed(16.0) == 4.0


def test_thaw_missing_artifact_raises():
    with pytest.raises(CodeShipError):
        thaw_function(None)


# ----------------------------------------------------------- worker host ----

@pytest.fixture
def manifest_deployment(tmp_path):
    path = str(tmp_path / "manifest.json")
    return path, Deployment(manifest_path=path)


def _pack_invoke(dep, fn, *args, name=None):
    deployed = dep.deploy(fn, *args)
    payload = deployed.bridge.pack(args, {}, {})
    return deployed, wire.encode_invoke(deployed.name, payload, task_id=1)


def test_worker_host_rebuilds_bridge_from_manifest(manifest_deployment):
    path, dep = manifest_deployment
    deployed, frame = _pack_invoke(dep, task_square_sum, jnp.ones(4))
    host = WorkerHost(path)          # fresh host: only the manifest in common
    msg = wire.decode(host.handle(frame))
    assert isinstance(msg, wire.ResultReply), msg
    assert msg.cold_start and msg.server_s > 0
    assert float(deployed.bridge.unpack_result(msg.blob)) == 4.0
    # warm on the second hit
    msg2 = wire.decode(host.handle(frame))
    assert isinstance(msg2, wire.ResultReply) and not msg2.cold_start


def test_worker_host_user_error_keeps_traceback(manifest_deployment):
    path, dep = manifest_deployment
    _, frame = _pack_invoke(dep, task_raise, 2)
    msg = wire.decode(WorkerHost(path).handle(frame))
    assert isinstance(msg, wire.ErrorReply) and not msg.retryable
    assert msg.etype == "ValueError" and "bad input 2" in msg.message
    assert "task_raise" in msg.traceback


def test_worker_host_unknown_function_is_visible_error(tmp_path):
    host = WorkerHost(str(tmp_path / "missing.json"))
    msg = wire.decode(host.handle(wire.encode_invoke("ghost", b"")))
    assert isinstance(msg, wire.ErrorReply)
    assert "ghost" in msg.message and not msg.retryable


def test_worker_host_malformed_request_is_visible_error(tmp_path):
    host = WorkerHost(str(tmp_path / "missing.json"))
    msg = wire.decode(host.handle(b"not a frame at all"))
    assert isinstance(msg, wire.ErrorReply) and not msg.retryable


def test_worker_host_control_ping_and_drain(manifest_deployment):
    path, dep = manifest_deployment
    _, frame = _pack_invoke(dep, task_square_sum, jnp.ones(2))
    host = WorkerHost(path)
    pong = wire.decode(host.handle(wire.encode_control("ping")))
    assert isinstance(pong, wire.ControlRequest) and pong.op == "pong"
    host.handle(frame)
    drained = wire.decode(host.handle(wire.encode_control("drain")))
    assert drained.op == "drained" and drained.data["count"] == 1
    # post-drain invocations pay the cold start again
    msg = wire.decode(host.handle(frame))
    assert isinstance(msg, wire.ResultReply) and msg.cold_start


# ------------------------------------------------------------ sandbox host --

def test_sandbox_host_cold_warm_drain_accounting():
    host = SandboxHost()
    entry = lambda payload: (payload, type("S", (), {   # noqa: E731
        "deserialize_s": 0.0, "compute_s": 0.0, "serialize_s": 0.0})())
    first = host.invoke(entry, "f", b"x")
    second = host.invoke(entry, "f", b"x")
    assert first.cold_start and not second.cold_start
    assert first.worker_id == second.worker_id          # warm reuse
    assert host.drain() == 1
    assert host.invoke(entry, "f", b"x").cold_start     # drained → cold


def test_sandbox_host_fault_injection_burns_sandbox():
    host = SandboxHost(FaultPlan(failure_rate=1.0, seed=1))
    with pytest.raises(WorkerCrash):
        host.invoke(lambda p: (p, None), "f", b"x", task_id=0, attempt=1)
    assert host.live_instances == 0


# ------------------------------------------- processes backend error paths --

@pytest.fixture(scope="module")
def proc_session():
    with Session("processes", os_threads=1) as sess:
        yield sess


def test_processes_user_error_surfaces_with_remote_traceback(proc_session):
    f = proc_session.function(task_raise, jax_traceable=False)
    with pytest.raises(ValueError, match="bad input 2") as ei:
        f.submit(2).result(timeout=300)
    assert "task_raise" in ei.value.remote_traceback


def test_processes_dead_worker_is_retryable_not_hung(proc_session):
    """The satellite regression: a worker that dies mid-request must surface
    as a retryable invocation error (WorkerCrash), never a hung future."""
    f = proc_session.function(task_hard_exit, jax_traceable=False,
                              max_retries=0)
    t0 = time.monotonic()
    with pytest.raises(WorkerCrash, match="died mid-request"):
        f.submit(0).result(timeout=300)
    assert time.monotonic() - t0 < 120
    # the slot respawns: the session keeps serving afterwards
    g = proc_session.function(task_square_sum, name="after_crash")
    assert float(g.submit(jnp.ones(3)).result(timeout=300)) == 3.0


def test_processes_base_exception_carries_original_traceback(proc_session):
    f = proc_session.function(task_base_exception, jax_traceable=False,
                              max_retries=0)
    with pytest.raises(WorkerCrash) as ei:
        f.submit(0).result(timeout=300)
    assert "SystemExit" in getattr(ei.value, "remote_traceback", "")


def test_processes_dead_worker_retry_can_succeed():
    """A crash on attempt 1 is retried on a fresh worker and succeeds."""
    with Session("processes", os_threads=1) as sess:
        marker = os.path.join(os.path.dirname(__file__), "..",
                              f".crash-once-{os.getpid()}")
        f = sess.function(task_crash_once, jax_traceable=False, max_retries=2)
        try:
            assert f.submit(marker).result(timeout=300) == "survived"
        finally:
            if os.path.exists(marker):
                os.unlink(marker)


def task_crash_once(marker):
    if not os.path.exists(marker):
        open(marker, "w").close()
        os._exit(1)            # first attempt: die mid-request
    return "survived"


# ------------------------------------------------- http: in-test worker -----

def test_http_backend_against_in_test_worker(tmp_path):
    """The paper's client model with the worker under test control: an
    in-process http.server thread serving the same manifest the session
    deploys into; records must carry *measured* latency."""
    path = str(tmp_path / "manifest.json")
    dep = Deployment(manifest_path=path)
    server = serve_http(path, port=0)
    port = server.server_address[1]
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    try:
        backend = HttpBackend(url=f"http://127.0.0.1:{port}",
                              manifest_path=path, os_threads=2)
        with Session(backend, deployment=dep) as sess:
            f = sess.function(task_square_sum, name="http_ssq", memory_mb=512)
            out = [float(v) for v in f.map([(jnp.ones(4) * i,)
                                            for i in range(4)])]
            assert out == [0.0, 4.0, 16.0, 36.0]
            assert all(r.latency_measured for r in sess.records)
            assert all(r.modeled_latency_ms > 0 for r in sess.records)
            assert any(r.cold_start for r in sess.records)
            assert sess.cost.invocations == 4
        backend.shutdown()
    finally:
        server.shutdown()
        server.server_close()


def test_http_worker_gone_is_retryable_error(tmp_path):
    """A vanished fleet (connection refused) surfaces as a retryable
    WorkerCrash, never a hung future."""
    path = str(tmp_path / "manifest.json")
    dep = Deployment(manifest_path=path)
    # grab a port that nothing listens on
    import socket
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    dead_port = s.getsockname()[1]
    s.close()
    backend = HttpBackend(url=f"http://127.0.0.1:{dead_port}",
                          manifest_path=path, os_threads=1)
    with Session(backend, deployment=dep) as sess:
        f = sess.function(task_square_sum, name="gone_ssq", max_retries=0)
        t0 = time.monotonic()
        with pytest.raises(WorkerCrash):
            f.submit(jnp.ones(2)).result(timeout=300)
        assert time.monotonic() - t0 < 120
    backend.shutdown()


# ------------------------------- worker-resident state + affinity (ISSUE 5) --

def task_pid(x):
    return os.getpid()


def task_state_note(handle, value):
    from repro.runtime import state
    data = state.lease(handle, ttl_s=60.0, make=dict)
    data["value"] = value
    return sorted(state.stats()["handles"])


def task_state_read(handle):
    from repro.runtime import state
    return state.get(handle)["value"]


def test_wire_control_frame_carries_body():
    frame = wire.encode_control("artifact_put", body=b"\x00blob", sha="abc")
    msg = wire.decode(frame)
    assert isinstance(msg, wire.ControlRequest)
    assert msg.op == "artifact_put"
    assert msg.data == {"sha": "abc"} and msg.body == b"\x00blob"


def test_affinity_pins_invocations_to_one_worker():
    """Invocations sharing an affinity key land on one worker process
    across calls (the resident-state prerequisite); the pin survives
    interleaved anonymous traffic on the same backend."""
    with Session("processes", os_threads=2) as sess:
        pinned = sess.function(task_pid, name="pid_pinned",
                               jax_traceable=False, affinity=0)
        free = sess.function(task_pid, name="pid_free", jax_traceable=False)
        pids = [pinned.submit(i).result(timeout=300) for i in range(4)]
        free.submit(0).result(timeout=300)
        pids.append(pinned.submit(9).result(timeout=300))
        assert len(set(pids)) == 1


def test_state_survives_across_invocations_and_control_release():
    """A lease written by one invocation is readable by the next (same
    affinity ⇒ same worker), visible to state_stats, and gone after the
    CONTROL state_release — the wire half of the state-lease op."""
    with Session("processes", os_threads=2) as sess:
        note = sess.function(task_state_note, jax_traceable=False, affinity=3)
        read = sess.function(task_state_read, jax_traceable=False, affinity=3)
        handles = note.submit("h-trans", 42).result(timeout=300)
        assert "h-trans" in handles
        assert read.submit("h-trans").result(timeout=300) == 42
        stats = sess.backend.state_control(3, "state_stats")
        assert "h-trans" in stats["handles"]
        out = sess.backend.state_control(3, "state_release",
                                         handle="h-trans")
        assert out["released"] is True
        with pytest.raises(KeyError, match="state handle"):
            read.submit("h-trans").result(timeout=300)


def task_artifact_sum(tree):
    import numpy as np
    return float(np.sum(tree["a"]))


def test_artifact_missing_on_worker_is_fetched_from_client():
    """Remote artifact fetch (ROADMAP satellite): the store file vanishes
    before a fresh worker resolves the ref — the worker reports
    ArtifactMissing, the client pushes the blob over a CONTROL frame, the
    invocation replays and succeeds, and the blob is re-deposited."""
    import numpy as np

    from repro.serialization import put_artifact, release_artifact

    value = {"a": np.arange(7, dtype=np.float32)}
    ref = put_artifact(value)
    try:
        os.unlink(ref.path)            # no shared file: only the client
        assert not os.path.exists(ref.path)  # has it (in-memory cache)
        with Session("processes", os_threads=1) as sess:
            f = sess.function(task_artifact_sum, jax_traceable=False)
            assert f.submit(ref).result(timeout=300) == 21.0
            # warm path: resolved from the worker's process cache now
            assert f.submit(ref).result(timeout=300) == 21.0
        assert os.path.exists(ref.path)      # fetched blob was deposited
    finally:
        release_artifact(ref)
        if os.path.exists(ref.path):
            os.unlink(ref.path)
