import os

# Tests must see exactly ONE device (the dry-run is the only 512-device
# context, and it sets its own XLA_FLAGS before jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ------------------------------------------------- ragged-batch invariance --
# Shared across test_apps_server.py (wave mode) and test_serving.py
# (continuous mode): one tiny model per family, float32 so the greedy
# argmax comparison proves algorithmic equality rather than bf16 luck.

FAMILY_ARCHS = {
    "dense": "smollm-360m",
    "moe": "phi3.5-moe-42b-a6.6b",
    "hybrid": "zamba2-2.7b",
    "ssm": "rwkv6-1.6b",
}


@pytest.fixture(scope="session", params=tuple(FAMILY_ARCHS),
                ids=tuple(FAMILY_ARCHS))
def lm_family(request):
    """(family, cfg, params) for one architecture family (session-cached:
    params init + entry-point compiles are the expensive part)."""
    import jax
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke(FAMILY_ARCHS[request.param]).replace(
        param_dtype="float32", compute_dtype="float32")
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    return request.param, cfg, params


def make_ragged_requests(cfg):
    """Mixed-length prompts with mixed decode lengths — the batch shape the
    maskless serve path used to get wrong.  One prompt deliberately
    contains the pad id (token 0): per-row lengths, not sentinel scanning,
    must be what separates content from padding."""
    from repro.runtime.server import Request
    rng = np.random.default_rng(7)
    prompts = [list(rng.integers(1, cfg.vocab_size, n)) for n in (5, 3, 8, 1)]
    prompts[1] = [cfg.pad_id, cfg.pad_id, prompts[1][-1]]
    max_news = (4, 8, 4, 4)
    return [Request(prompt=p, max_new=m) for p, m in zip(prompts, max_news)]


def solo_reference(server, requests):
    """Reference greedy tokens: every request served ALONE (batch of one)
    through the SAME server/backend that will serve the packed batch —
    invariance is a property of batch composition, so the solo run must
    share the packed run's numerics (a worker subprocess may partition
    matmuls differently from the client process, and MoE routing can flip
    on 1-ulp router differences)."""
    return [server.serve_wave([r])[0].tokens for r in requests]
