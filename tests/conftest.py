import os

# Tests must see exactly ONE device (the dry-run is the only 512-device
# context, and it sets its own XLA_FLAGS before jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
