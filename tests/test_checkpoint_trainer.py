"""Checkpoint store + fault-tolerant trainer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_smoke
from repro.runtime import train


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    out = restore(str(tmp_path), 3, jax.tree.map(lambda x: x, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_commit_marker_and_discovery(tmp_path):
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 5, _tree())
    save(str(tmp_path), 9, _tree())
    # an uncommitted (torn) checkpoint must be ignored
    os.makedirs(tmp_path / "step_00000012")
    assert latest_step(str(tmp_path)) == 9


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    ck.close()
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_train_loss_decreases():
    cfg = get_smoke("smollm-360m")
    rep = train(cfg, steps=30, global_batch=4, seq_len=32, peak_lr=5e-3)
    assert rep.steps_run == 30
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first, (first, last)


def test_train_survives_preemption_and_resumes(tmp_path):
    """Kill the 'node' mid-run; the loop restores the newest committed
    checkpoint and finishes with the same final loss as an undisturbed run
    (deterministic data skip-ahead)."""
    cfg = get_smoke("smollm-360m")
    kw = dict(steps=24, global_batch=4, seq_len=32, peak_lr=5e-3,
              ckpt_every=8)
    clean = train(cfg, ckpt_dir=str(tmp_path / "clean"), **kw)
    faulty = train(cfg, ckpt_dir=str(tmp_path / "faulty"),
                   fail_at={13, 19}, **kw)
    assert faulty.restarts == 2
    assert faulty.restored_from  # recovery actually used a checkpoint
    assert abs(clean.final_loss - faulty.final_loss) < 0.05, \
        (clean.final_loss, faulty.final_loss)


def test_restart_from_disk_continues(tmp_path):
    """A brand-new process picks up where the old one died."""
    cfg = get_smoke("smollm-360m")
    kw = dict(global_batch=4, seq_len=32, peak_lr=5e-3, ckpt_every=5)
    train(cfg, steps=10, ckpt_dir=str(tmp_path), **kw)
    rep2 = train(cfg, steps=20, ckpt_dir=str(tmp_path), **kw)
    assert rep2.restored_from and rep2.restored_from[0] == 10
    assert rep2.steps_run == 10          # only the remaining steps
