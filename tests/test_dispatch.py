"""Dispatcher runtime tests: fork-join, retry, hedging, cost, latency model."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import FunctionConfig, RemoteFunction
from repro.dispatch import (DEFAULT_LATENCY, Dispatcher, FaultPlan,
                            LatencyModel, dispatch, wait)


@pytest.fixture()
def disp():
    d = Dispatcher(os_threads=8)
    yield d
    d.shutdown()


def test_pi_estimation_paper_fig6(disp):
    """The paper's flagship example: parallel PI via 128 lambda tasks."""
    n = 200_000
    np_ = 32
    inst = disp.create_instance()
    cfg = (FunctionConfig()
           .with_memory(512)
           .with_ephemeral_storage(64))

    def pi_estimate(seed):
        import jax
        k = jax.random.key(seed)
        pts = jax.random.uniform(k, (n // np_, 2))
        return 4.0 * jnp.mean((pts ** 2).sum(-1) <= 1.0)

    futs = [dispatch(inst, pi_estimate, i, config=cfg) for i in range(np_)]
    wait(inst)
    pi = float(np.mean([f.result() for f in futs]))
    assert abs(pi - 3.14159) < 0.05
    # one deployed function, many invocations (type-keyed dedup)
    assert disp.deployment.compile_count == 1
    assert inst.cost.invocations == np_
    assert inst.cost.gb_seconds > 0


def test_wait_n_semantics(disp):
    inst = disp.create_instance()
    futs = [inst.dispatch(lambda x: x * 2, jnp.float32(i)) for i in range(8)]
    inst.wait()  # all
    assert all(f.done() for f in futs)
    assert sorted(float(f.result()) for f in futs) == [0, 2, 4, 6, 8, 10, 12, 14]


def test_map_fork_join(disp):
    inst = disp.create_instance()
    out = inst.map(lambda x: jnp.sum(x),
                   [(jnp.ones(4) * i,) for i in range(5)])
    assert [float(o) for o in out] == [0.0, 4.0, 8.0, 12.0, 16.0]


def test_retry_on_worker_crash():
    """Fault tolerance: sandbox loss is retried transparently."""
    d = Dispatcher(os_threads=4,
                   fault_plan=FaultPlan(failure_rate=0.3, seed=42))
    try:
        inst = d.create_instance()
        cfg = FunctionConfig(max_retries=8)
        out = inst.map(lambda x: x + 1,
                       [(jnp.float32(i),) for i in range(20)], config=cfg)
        assert [float(o) for o in out] == [float(i + 1) for i in range(20)]
        assert sum(r.attempts for r in inst.records) > 20  # retries happened
    finally:
        d.shutdown()


def test_crash_without_retry_budget_raises():
    d = Dispatcher(os_threads=2,
                   fault_plan=FaultPlan(failure_rate=1.0, seed=1))
    try:
        inst = d.create_instance()
        cfg = FunctionConfig(max_retries=1)
        fut = inst.dispatch(lambda x: x, jnp.float32(0), config=cfg)
        with pytest.raises(Exception):
            fut.result(timeout=30)
    finally:
        d.shutdown()


def test_hedging_mitigates_stragglers():
    """Beyond-paper: backup requests cut the tail the paper observed."""
    d = Dispatcher(os_threads=8,
                   fault_plan=FaultPlan(straggler_rate=0.2,
                                        straggler_sleep_s=0.5, seed=7))
    try:
        inst = d.create_instance()
        out = inst.map(lambda x: x * 2, [(jnp.float32(i),) for i in range(10)],
                       hedge_quantile=0.5)
        assert [float(o) for o in out] == [2.0 * i for i in range(10)]
    finally:
        d.shutdown()


def test_cold_warm_accounting(disp):
    inst = disp.create_instance()
    inst.map(lambda x: x, [(jnp.float32(i),) for i in range(12)])
    cold = sum(1 for r in inst.records if r.cold_start)
    assert 1 <= cold <= 8        # ≤ os_threads sandboxes provisioned
    # drain & re-invoke: cold starts again (elastic scale-in)
    disp.pool.drain_warm()
    inst2 = disp.create_instance()
    inst2.map(lambda x: x, [(jnp.float32(0),)])
    assert inst2.records[0].cold_start


def test_cost_model_flat_with_parallelism(disp):
    """Fig 14's claim: GB-s cost ~independent of the parallelism scale."""
    def run(ntasks, total=64):
        inst = disp.create_instance()
        size = total // ntasks
        inst.map(lambda x: jnp.sum(x * x),
                 [(jnp.ones((size, 64)),) for _ in range(ntasks)])
        return inst.cost.compute_seconds

    c8, c32 = run(8), run(32)
    # total productive compute should not grow dramatically with parallelism
    assert c32 < c8 * 20


def test_latency_model_fig11_shape():
    """Fig 11: ~50 ms single; ~linear to ~150 ms near the stream budget;
    queuing growth beyond it; HTTP/1.1 client slower than HTTP/2 pool."""
    m = DEFAULT_LATENCY
    single = m.simulate_burst([20.0])[0]
    assert 40 <= single <= 120
    k = 1500
    lats = m.simulate_burst([20.0] * k)
    assert np.mean(lats[:100]) < np.mean(lats[-100:])   # grows with pressure
    mid = m.simulate_burst([20.0] * 1000)
    assert 100 <= np.mean(mid[900:]) <= 400
    # beyond capacity (16*100=1600): queuing kicks in
    over = m.simulate_burst([20.0] * 4000)
    assert np.mean(over[-100:]) > np.mean(mid[-100:])
    # HTTP/1.1 per-request client pays handshakes
    h1 = m.simulate_burst([20.0] * 100, client="http1_per_request")
    h2 = m.simulate_burst([20.0] * 100, client="http2_pool")
    assert np.mean(h1) > np.mean(h2)


def test_dispatch_rate_ten_per_ms():
    """Paper: 'client dispatches ~10 invocations per millisecond'."""
    m = LatencyModel()
    lats = m.simulate_burst([0.0] * 1000)
    # issue times span ~100 ms for 1000 invocations
    assert 80 <= (max(lats) - lats[0]) <= 250


def test_modeled_instance_metrics(disp):
    inst = disp.create_instance()
    inst.map(lambda x: jnp.sum(x), [(jnp.ones(16),) for _ in range(4)])
    lats = inst.modeled_latencies_ms()
    assert len(lats) == 4 and all(l > 0 for l in lats)
    assert inst.modeled_makespan_ms() >= max(lats) - 1e-9


def test_instances_are_namespaces(disp):
    a, b = disp.create_instance(), disp.create_instance()
    a.map(lambda x: x, [(jnp.float32(1),)])
    assert b.cost.invocations == 0 and a.cost.invocations == 1
