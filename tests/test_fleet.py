"""Fleet serving (ISSUE 6): prefix-aware routing, disaggregated
prefill/decode hand-off, elastic grow/drain, sandbox cold/warm + busy-time
accounting, and the scale-in × affinity safety contract — scale-down must
refuse to strand a pinned worker's live state leases."""
import asyncio
import random
import time
import types

import jax
import pytest

from conftest import make_ragged_requests, solo_reference
from repro.cloud import Session
from repro.fleet import FleetController, FleetRouter, FleetStats, run_fleet
from repro.runtime.engine import prefix_key
from repro.runtime.sandbox import SandboxHost
from repro.runtime.server import LMServer, Request


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------- sandbox cold/warm accounting ----

def test_sandbox_host_counts_cold_warm_and_busy_time():
    host = SandboxHost()

    def entry(payload):
        time.sleep(0.002)
        return payload, types.SimpleNamespace()

    host.invoke(entry, "f", b"a")            # cold
    host.invoke(entry, "f", b"b")            # warm reuse
    host.invoke(entry, "g", b"c")            # second function: its own cold
    st = host.stats()
    assert st["cold_starts"] == 2 and st["warm_hits"] == 1
    assert st["busy_s"] >= 0.006
    assert st["functions"]["f"]["cold_starts"] == 1
    assert st["functions"]["f"]["warm_hits"] == 1
    assert st["functions"]["f"]["busy_s"] >= 0.004
    assert st["functions"]["g"]["cold_starts"] == 1
    assert st["functions"]["g"]["warm_hits"] == 0


def test_sandbox_host_busy_time_counted_even_when_entry_raises():
    host = SandboxHost()

    def entry(payload):
        time.sleep(0.002)
        raise ValueError("boom")

    with pytest.raises(ValueError):
        host.invoke(entry, "f", b"x")
    st = host.stats()
    assert st["busy_s"] >= 0.002 and st["cold_starts"] == 1


def task_square(x):
    return x * x


def test_session_stats_surfaces_fleet_accounting():
    with Session("threads", os_threads=2) as sess:
        f = sess.function(task_square, jax_traceable=False)
        assert [f.submit(i).result(timeout=300) for i in (2, 3, 4)] == \
            [4, 9, 16]
        st = sess.stats()
        assert st["inflight"] == 0 and st["queue_depth"] == 0
        assert st["cold_starts"] >= 1
        assert st["cold_starts"] + st["warm_hits"] >= 3
        assert st["busy_s"] > 0


# --------------------------------- scale-in × affinity (the regression) ----

def task_state_note(handle, value):
    from repro.runtime import state
    state.lease(handle, ttl_s=60.0, make=dict)["value"] = value
    return value


def test_scale_in_refuses_to_strand_pinned_state_leases():
    """scale_to below a pinned worker's slot must refuse while that worker
    holds live state leases (re-homing the frozen affinity would hand the
    engine a blank arena mid-serve), and succeed after release."""
    with Session("processes", os_threads=1) as sess:
        sess.backend.scale_to(2)
        note = sess.function(task_state_note, jax_traceable=False,
                             affinity=1)
        assert note.submit("h-fleet", 5).result(timeout=300) == 5
        assert sess.backend._affinity_slots[1] == 1    # frozen on worker 1
        with pytest.raises(RuntimeError, match="strand live state leases"):
            sess.backend.scale_to(1)
        assert sess.stats()["n_workers"] == 2          # nothing was re-homed
        sess.backend.state_control(1, "state_release", handle="h-fleet")
        sess.backend.scale_to(1)                       # lease gone: allowed
        assert sess.stats()["n_workers"] == 1


# -------------------------------------------------- routing policy unit ----

def _stub_member(index, load, rows=4, draining=False):
    loop = types.SimpleNamespace(load=load, rows=rows, draining=draining,
                                 free_rows=max(0, rows - load))
    return types.SimpleNamespace(index=index, loop=loop, role="unified")


def _stub_router(policy="prefix", spill_factor=2.0, prefix_len=None,
                 seed=0):
    r = FleetRouter.__new__(FleetRouter)
    r.policy = policy
    r.prefix_len = prefix_len
    r.spill_factor = spill_factor
    r._rng = random.Random(seed)
    r._owners = {}
    r.stats = FleetStats()
    return r


def test_prefix_policy_pins_repeats_to_the_owner():
    r = _stub_router()
    a, b = _stub_member(0, 0), _stub_member(1, 0)
    owner, how = r._choose([1, 2, 3], [a, b])
    assert how == "p2c"                      # first sight claims ownership
    for _ in range(5):
        m, how = r._choose([1, 2, 3], [a, b])
        assert m is owner and how == "prefix"
    # a different prompt may claim the other member; it never steals
    r._choose([9, 9, 9], [a, b])
    assert r._owners[prefix_key([1, 2, 3])] is owner


def test_prefix_len_truncates_the_routing_key():
    r = _stub_router(prefix_len=2)
    a, b = _stub_member(0, 0), _stub_member(1, 0)
    owner, _ = r._choose([5, 6, 1, 2], [a, b])
    m, how = r._choose([5, 6, 9, 9], [a, b])  # same first-2 prefix
    assert m is owner and how == "prefix"
    assert prefix_key([5, 6]) in r._owners


def test_overloaded_owner_spills_without_losing_ownership():
    r = _stub_router(spill_factor=2.0)
    a, b = _stub_member(0, 0, rows=4), _stub_member(1, 0, rows=4)
    owner, _ = r._choose([1, 2], [a, b])
    other = b if owner is a else a
    owner.loop.load = 8                      # at spill_factor × rows
    m, how = r._choose([1, 2], [a, b])
    assert m is other and how == "p2c"
    assert r.stats.spills == 1
    owner.loop.load = 1                      # overload passed: pin returns
    m, how = r._choose([1, 2], [a, b])
    assert m is owner and how == "prefix"


def test_unroutable_owner_is_reassigned():
    """A draining/dead owner falls out of the target set: the key is
    re-claimed by a live member instead of routing into a drain."""
    r = _stub_router()
    a, b = _stub_member(0, 0), _stub_member(1, 0)
    owner, _ = r._choose([4, 4], [a, b])
    survivor = b if owner is a else a
    m, _ = r._choose([4, 4], [survivor])     # owner no longer routable
    assert m is survivor
    assert r._owners[prefix_key([4, 4])] is survivor


def test_p2c_picks_less_loaded_of_two():
    r = _stub_router(policy="p2c")
    members = [_stub_member(0, 9), _stub_member(1, 0)]
    picks = {r._p2c(members).index for _ in range(20)}
    assert picks == {1}


def test_radix_policy_routes_longest_shared_prefix_to_owner():
    """ISSUE 7: unlike ``prefix`` (whole-prompt hash), the radix policy
    routes any prompt *sharing a block-aligned head* with a claimed run
    to that run's owner — extensions and partial overlaps included."""
    from repro.runtime.radix import RadixIndex

    r = _stub_router(policy="radix")
    r._radix = RadixIndex(4, budget_tokens=1 << 16)
    a, b = _stub_member(0, 0), _stub_member(1, 0)
    head = [3, 1, 4, 1, 5, 9, 2, 6]
    owner, how = r._choose(list(head), [a, b])
    assert how == "p2c"                      # first sight claims the head
    # an extension (NOT an exact repeat) still routes to the owner
    m, how = r._choose(head + [99, 98, 97], [a, b])
    assert m is owner and how == "prefix"
    # a partial overlap (first block only) routes there too
    m, how = r._choose(head[:4] + [7, 7, 7, 7], [a, b])
    assert m is owner and how == "prefix"
    # overload spills via p2c without reclaiming the runs
    owner.loop.load = 8
    m, how = r._choose(list(head), [a, b])
    assert m is not owner and how == "p2c"
    assert r.stats.spills == 1
    owner.loop.load = 0
    m, how = r._choose(list(head), [a, b])
    assert m is owner and how == "prefix"
    # a prompt shorter than one block can never be claimed or matched
    m, how = r._choose([5, 5], [a, b])
    assert how == "p2c" and r._radix.match([5, 5]) == (0, [])


# ---------------------------------------------------- controller policy ----

class _StubFleet:
    def __init__(self, members, backlog=0):
        self.members = members
        self.backlog = backlog
        self.events = []
        self._closed = False

    @property
    def active_members(self):
        return self.members

    def grow(self, reason=""):
        self.events.append("grow")
        return self.members[0]

    def drain(self, reason=""):
        self.events.append("drain")
        return self.members[0]


def test_controller_grows_on_backlog_and_respects_cooldown():
    fleet = _StubFleet([_stub_member(0, 4, rows=4)], backlog=6)
    ctl = FleetController(fleet, max_members=3, grow_cooldown_s=10.0)
    assert ctl.step(now=0.0) == "grow"
    assert ctl.step(now=1.0) is None         # cooling down
    assert ctl.step(now=11.0) == "grow"
    assert fleet.events == ["grow", "grow"]


def test_controller_grow_capped_at_max_members():
    fleet = _StubFleet([_stub_member(i, 4, rows=4) for i in range(2)],
                       backlog=9)
    ctl = FleetController(fleet, max_members=2, grow_cooldown_s=0.0)
    assert ctl.step(now=0.0) is None
    assert fleet.events == []


def test_controller_drains_only_after_sustained_low_occupancy():
    fleet = _StubFleet([_stub_member(0, 0), _stub_member(1, 0)], backlog=0)
    ctl = FleetController(fleet, max_members=3, patience=3,
                          shrink_occupancy=0.25)
    assert [ctl.step(now=float(i)) for i in range(3)] == \
        [None, None, "drain"]
    # a busy sample resets the patience window
    fleet.events.clear()
    fleet.members[0].loop.load = 4
    fleet.members[0].loop.free_rows = 0
    assert ctl.step(now=10.0) is None
    fleet.members[0].loop.load = 0
    fleet.members[0].loop.free_rows = 4
    assert [ctl.step(now=11.0 + i) for i in range(3)] == \
        [None, None, "drain"]


def test_controller_never_drains_below_min_members():
    fleet = _StubFleet([_stub_member(0, 0)], backlog=0)
    ctl = FleetController(fleet, max_members=3, min_members=1, patience=1)
    assert all(ctl.step(now=float(i)) is None for i in range(5))
    assert fleet.events == []


# ------------------------------------------------- router end to end ----

def _dup_requests(cfg):
    base = make_ragged_requests(cfg)
    return base + [Request(prompt=list(base[0].prompt), max_new=6),
                   Request(prompt=list(base[2].prompt), max_new=3)]


def test_fleet_rejects_non_resident_backends_and_bad_policy(lm_setup):
    cfg, params = lm_setup
    with Session("sim-aws", os_threads=2) as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        with pytest.raises(ValueError, match="resident-state backend"):
            FleetRouter(server)
        server.close(prune=False)
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        with pytest.raises(ValueError, match="routing policy"):
            FleetRouter(server, policy="round-robin")
        server.close(prune=False)


def test_fleet_prefix_routing_is_solo_identical(lm_setup):
    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = _dup_requests(cfg)
        solo = solo_reference(server, reqs)
        comps, s = run_fleet(server, reqs, n_members=3, policy="prefix",
                             max_batch=3, quantum=4, prompt_cap=16,
                             return_stats=True)
        assert [c.tokens for c in comps] == solo
        assert s["routing"]["prefix"] >= 1   # the duplicates were pinned
        assert s["n_members"] == 3
        server.close(prune=False)


def test_fleet_disaggregated_handoff_is_solo_identical(lm_setup):
    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = _dup_requests(cfg)
        solo = solo_reference(server, reqs)
        comps, s = run_fleet(server, reqs, n_members=3, policy="p2c",
                             disaggregate=True, prefill_members=1,
                             max_batch=3, quantum=4, prompt_cap=16,
                             return_stats=True)
        assert [c.tokens for c in comps] == solo
        assert s["handoffs"] >= 1
        assert s["batcher"]["migrated_rows"] >= 1
        roles = {m["role"] for m in s["members"]}
        assert roles == {"prefill", "decode"}
        # migration must not cost TTFT observability
        assert all(c.ttft_ms is not None for c in comps)
        server.close(prune=False)


def test_fleet_elastic_grows_under_backlog_and_stays_identical(lm_setup):
    cfg, params = lm_setup
    import numpy as np
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 4 + i % 3)),
                    max_new=4 + i % 3) for i in range(24)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        solo = solo_reference(server, reqs)
        comps, s = run_fleet(
            server, reqs, concurrency=24, n_members=3, policy="prefix",
            elastic=True, min_members=1,
            controller=dict(interval_s=0.002, grow_cooldown_s=0.0),
            max_batch=2, quantum=4, prompt_cap=16, return_stats=True)
        assert [c.tokens for c in comps] == solo
        grows = [e for e in s["scale_events"] if e["action"] == "grow"]
        assert grows, s["scale_events"]      # backlog forced a scale-up
        assert s["n_members"] > 1
        server.close(prune=False)


def test_fleet_drain_loses_no_inflight_requests(lm_setup):
    """Cooperative scale-down: the drained member leaves the routing set,
    serves out everything it owns, and every request still completes with
    solo-identical tokens — zero loss."""
    cfg, params = lm_setup
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = _dup_requests(cfg)
        solo = solo_reference(server, reqs)

        async def go():
            async with FleetRouter(server, n_members=2, policy="p2c",
                                   max_batch=2, quantum=4,
                                   prompt_cap=16) as fleet:
                tasks = [asyncio.ensure_future(fleet.submit(r))
                         for r in reqs]
                await asyncio.sleep(0)       # queues populated, decode live
                drained = fleet.drain(fleet.members[0], reason="test")
                assert drained is fleet.members[0]
                assert not fleet.members[0].active
                comps = await asyncio.gather(*tasks)
                return comps, fleet.summary()

        comps, s = asyncio.run(go())
        assert [c.tokens for c in comps] == solo
        assert [e["action"] for e in s["scale_events"]] == ["drain"]
        served = sum(m["served"] for m in s["members"])
        assert served + s["batcher"]["wave_fallbacks"] == len(reqs)
        server.close(prune=False)


def test_fleet_long_prompt_falls_back_to_solo_wave(lm_setup):
    cfg, params = lm_setup
    import numpy as np
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=[1, 2, 3], max_new=3),
            Request(prompt=list(rng.integers(1, cfg.vocab_size, 40)),
                    max_new=3)]
    with Session("inline") as sess:
        server = LMServer(cfg, params, session=sess, max_new=4)
        solo = solo_reference(server, reqs)
        comps, s = run_fleet(server, reqs, n_members=2, policy="prefix",
                             max_batch=2, quantum=4, prompt_cap=8,
                             return_stats=True)
        assert [c.tokens for c in comps] == solo
        assert s["batcher"]["wave_fallbacks"] == 1
        server.close(prune=False)
