"""Paper applications + serverless LM serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_ragged_requests
from repro.apps import (KNOWN, compute_pi, prefixes, random_scene,
                        render_serial, render_serverless, solve_serial,
                        solve_serverless)
from repro.cloud import Session
from repro.configs import get_smoke
from repro.dispatch import Dispatcher
from repro.models import build_model
from repro.models.api import grow_cache
from repro.runtime import LMServer, Request, pack_prompts


def test_nqueens_serial_known():
    for n in (5, 6, 7, 8):
        assert solve_serial(n) == KNOWN[n]


def test_nqueens_prefix_decomposition_complete():
    """Prefix tasks partition the search space: counts sum to the total."""
    for n, p in ((7, 1), (7, 2), (8, 2)):
        total, ntasks, _ = solve_serverless(n, p)
        assert total == KNOWN[n], (n, p, total)
        assert ntasks == len(prefixes(n, p))


def test_nqueens_longer_prefix_more_tasks():
    assert len(prefixes(9, 2)) > len(prefixes(9, 1))


def test_pi_estimate():
    pi, inst = compute_pi(100_000, 8)
    assert abs(pi - np.pi) < 0.05
    assert inst.cost.invocations == 8


def test_raytracer_serverless_matches_serial_statistics():
    sc = random_scene(width=32, height=32, n_spheres=6)
    a = render_serial(sc, spp=2)
    b, inst = render_serverless(sc, tile=16, spp=2)
    assert b.shape == (32, 32, 3) and np.isfinite(b).all()
    # different MC seeds per tile -> compare statistics, not pixels
    assert abs(a.mean() - b.mean()) < 0.05
    assert inst.cost.invocations == 4
    assert inst.cost.gb_seconds > 0


def test_raytracer_tile_count_scales():
    sc = random_scene(width=32, height=32, n_spheres=4)
    _, i16 = render_serverless(sc, tile=16, spp=1)
    _, i8 = render_serverless(sc, tile=8, spp=1)
    assert i8.cost.invocations == 4 * i16.cost.invocations


# ------------------------------------------------ ragged batching (pack) ---

def test_pack_prompts_returns_lengths_and_all_pad_fillers():
    tokens, lengths = pack_prompts([[5, 0, 7], [9]], pad=3, min_rows=4)
    assert tokens.shape == (4, 4) and tokens.dtype == np.int32
    assert list(lengths) == [3, 1, 0, 0]
    np.testing.assert_array_equal(tokens[0], [3, 5, 0, 7])   # left-padded
    np.testing.assert_array_equal(tokens[1], [3, 3, 3, 9])
    assert (tokens[2:] == 3).all()       # filler rows all-pad, length 0


def test_pack_prompts_pad_id_not_a_sentinel():
    """A prompt may legitimately CONTAIN the pad id: lengths are the source
    of truth, so its tokens survive packing verbatim."""
    tokens, lengths = pack_prompts([[0, 0, 4, 0]], pad=0)
    np.testing.assert_array_equal(tokens[0], [0, 0, 4, 0])
    assert list(lengths) == [4]


def test_pack_prompts_rejects_empty_inputs():
    with pytest.raises(ValueError, match="empty prompt list"):
        pack_prompts([])
    with pytest.raises(ValueError, match="prompt 1 is empty"):
        pack_prompts([[1, 2], []])


# -------------------------------- batch-composition invariance (wave mode) --
# The acceptance property of the pad-mask work: greedy decode of a prompt
# is identical whether it was submitted alone or packed into a ragged
# batch — per family, per backend, with mixed max_new (bucket trimming)
# and a prompt that contains the pad id.

@pytest.mark.parametrize("backend", ("inline", "processes"))
def test_wave_ragged_batch_is_composition_invariant(lm_family, backend):
    from conftest import solo_reference

    _, cfg, params = lm_family
    with Session(backend, os_threads=1) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        reqs = make_ragged_requests(cfg)
        solo = solo_reference(server, reqs)
        comps = server.unpack_wave(reqs, server.submit_wave(reqs))
        assert [c.tokens for c in comps] == solo
        server.close(prune=False)


def test_fully_masked_filler_rows_decode_finite(lm_family):
    """min_rows pinning adds all-pad filler rows (length 0): every row of
    every entry point must stay finite — a fully masked softmax row must
    not NaN-poison the batch."""
    _, cfg, params = lm_family
    model = build_model(cfg)
    tokens, lengths = pack_prompts([[1, 2, 3]], pad=cfg.pad_id, min_rows=4)
    assert list(lengths) == [3, 0, 0, 0]
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(tokens),
                 "lengths": jnp.asarray(lengths)})
    assert bool(jnp.all(jnp.isfinite(logits)))
    cache = grow_cache(cfg, cache, tokens.shape[1] + 4)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for _ in range(4):
        logits, cache = model.decode(params, cache, tok)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]


def test_lm_server_serves_and_bills():
    cfg = get_smoke("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    server = LMServer(cfg, params, max_new=4)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 8)),
                    max_new=4) for _ in range(4)]
    comps = server.serve(reqs, wave_size=2)
    assert len(comps) == 4
    assert all(len(c.tokens) == 4 for c in comps)
    assert server.cost_report.invocations == 2          # two waves
    assert server.cost_report.gb_seconds > 0
    # determinism: same prompts -> same greedy tokens
    comps2 = server.serve(reqs, wave_size=2)
    assert [c.tokens for c in comps] == [c.tokens for c in comps2]
