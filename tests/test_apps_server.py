"""Paper applications + serverless LM serving."""
import jax
import numpy as np
import pytest

from repro.apps import (KNOWN, compute_pi, prefixes, random_scene,
                        render_serial, render_serverless, solve_serial,
                        solve_serverless)
from repro.configs import get_smoke
from repro.dispatch import Dispatcher
from repro.models import build_model
from repro.runtime import LMServer, Request


def test_nqueens_serial_known():
    for n in (5, 6, 7, 8):
        assert solve_serial(n) == KNOWN[n]


def test_nqueens_prefix_decomposition_complete():
    """Prefix tasks partition the search space: counts sum to the total."""
    for n, p in ((7, 1), (7, 2), (8, 2)):
        total, ntasks, _ = solve_serverless(n, p)
        assert total == KNOWN[n], (n, p, total)
        assert ntasks == len(prefixes(n, p))


def test_nqueens_longer_prefix_more_tasks():
    assert len(prefixes(9, 2)) > len(prefixes(9, 1))


def test_pi_estimate():
    pi, inst = compute_pi(100_000, 8)
    assert abs(pi - np.pi) < 0.05
    assert inst.cost.invocations == 8


def test_raytracer_serverless_matches_serial_statistics():
    sc = random_scene(width=32, height=32, n_spheres=6)
    a = render_serial(sc, spp=2)
    b, inst = render_serverless(sc, tile=16, spp=2)
    assert b.shape == (32, 32, 3) and np.isfinite(b).all()
    # different MC seeds per tile -> compare statistics, not pixels
    assert abs(a.mean() - b.mean()) < 0.05
    assert inst.cost.invocations == 4
    assert inst.cost.gb_seconds > 0


def test_raytracer_tile_count_scales():
    sc = random_scene(width=32, height=32, n_spheres=4)
    _, i16 = render_serverless(sc, tile=16, spp=1)
    _, i8 = render_serverless(sc, tile=8, spp=1)
    assert i8.cost.invocations == 4 * i16.cost.invocations


def test_lm_server_serves_and_bills():
    cfg = get_smoke("smollm-360m")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    server = LMServer(cfg, params, max_new=4)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size, 8)),
                    max_new=4) for _ in range(4)]
    comps = server.serve(reqs, wave_size=2)
    assert len(comps) == 4
    assert all(len(c.tokens) == 4 for c in comps)
    assert server.cost_report.invocations == 2          # two waves
    assert server.cost_report.gb_seconds > 0
    # determinism: same prompts -> same greedy tokens
    comps2 = server.serve(reqs, wave_size=2)
    assert [c.tokens for c in comps] == [c.tokens for c in comps2]
