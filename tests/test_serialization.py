"""Serialization layer tests (paper §5.1 substrate)."""
import dataclasses
import json

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serialization import (FORMATS, deserialize, flatten,
                                 register_custom, serialize, unflatten)


def trees_equal(a, b):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (np.asarray(a).dtype == np.asarray(b).dtype
                and np.asarray(a).shape == np.asarray(b).shape
                and np.array_equal(np.asarray(a), np.asarray(b)))
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(trees_equal(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(trees_equal(x, y) for x, y in zip(a, b)))
    return a == b and type(a) is type(b)


SAMPLE = {
    "weights": np.arange(24, dtype=np.float32).reshape(2, 3, 4),
    "step": 7,
    "lr": 1e-3,
    "tags": ["a", "b"],
    "nested": {"flag": True, "blob": b"\x00\x01\xff", "none": None},
    "tup": (np.array([1, 2], dtype=np.int64), "x"),
}


@pytest.mark.parametrize("fmt", FORMATS)
def test_roundtrip_all_formats(fmt):
    data = serialize(SAMPLE, format=fmt)
    assert isinstance(data, bytes)
    out = deserialize(data, format=fmt)
    assert trees_equal(SAMPLE, out)


@pytest.mark.parametrize("fmt", FORMATS)
def test_sniffing(fmt):
    data = serialize(SAMPLE, format=fmt)
    out = deserialize(data)  # format inferred
    assert trees_equal(SAMPLE, out)


def test_sniffing_envelope_is_layout_independent():
    """Sniffing must parse the envelope's ``format`` field, not match a byte
    prefix: key order, whitespace, and indentation are producer choices."""
    doc = json.loads(serialize(SAMPLE, format="binary_json").decode())
    variants = [
        # reordered keys: "payload" first
        json.dumps({"payload": doc["payload"], "format": "binary_json"}),
        # pretty-printed (space after colon, newlines)
        json.dumps(doc, indent=2),
        # leading whitespace before the envelope
        "  \n" + json.dumps(doc),
    ]
    for v in variants:
        assert trees_equal(SAMPLE, deserialize(v.encode())), v[:40]


def test_sniffing_unknown_format_field_raises():
    with pytest.raises(ValueError):
        deserialize(json.dumps({"format": "protobuf", "payload": ""}).encode())


def test_binary_zstd_roundtrip():
    pytest.importorskip("zstandard")
    data = serialize(SAMPLE, format="binary", compress=True)
    raw = serialize(SAMPLE, format="binary")
    out = deserialize(data)
    assert trees_equal(SAMPLE, out)
    # zeros-heavy payload should compress
    big = {"z": np.zeros((1024, 1024), np.float32)}
    assert len(serialize(big, compress=True)) < len(serialize(big)) / 10


def test_jax_arrays_become_numpy():
    import jax.numpy as jnp

    tree = {"x": jnp.ones((4, 4), jnp.bfloat16)}
    out = deserialize(serialize(tree))
    assert isinstance(out["x"], np.ndarray)
    assert str(out["x"].dtype) == "bfloat16"
    assert np.array_equal(out["x"].astype(np.float32), np.ones((4, 4), np.float32))


def test_custom_type_cereal_style():
    @dataclasses.dataclass
    class SceneCfg:
        width: int
        height: int
        name: str

    register_custom(SceneCfg)
    tree = {"cfg": SceneCfg(500, 500, "weekend")}
    out = deserialize(serialize(tree))
    assert out["cfg"] == SceneCfg(500, 500, "weekend")


def test_unregistered_type_raises():
    class Opaque:
        pass

    with pytest.raises(TypeError):
        serialize({"o": Opaque()})


def test_binary_json_is_valid_json():
    """AWS Lambda requires the payload to be a valid JSON object (paper §5.1)."""
    doc = json.loads(serialize(SAMPLE, format="binary_json").decode())
    assert doc["format"] == "binary_json"
    assert isinstance(doc["payload"], str)


def test_structured_json_is_pure_json():
    doc = json.loads(serialize({"a": np.arange(3)}, format="structured_json"))
    assert doc["leaves"][0]["data"] == [0, 1, 2]


# ------------------------------------------------------ property tests ------

_DTYPES = [np.float32, np.float64, np.int32, np.int64, np.uint8, np.uint64,
           np.bool_, np.float16]

leaf_st = st.one_of(
    st.integers(min_value=-(2**62), max_value=2**62),
    st.floats(allow_nan=False, allow_infinity=False, width=64),
    st.booleans(),
    st.text(max_size=20),
    st.binary(max_size=20),
    st.sampled_from(_DTYPES).flatmap(
        lambda dt: st.integers(0, 3).flatmap(
            lambda nd: st.lists(st.integers(1, 4), min_size=nd, max_size=nd).map(
                lambda shape: np.arange(int(np.prod(shape)) if shape else 1)
                .reshape(shape or ())
                .astype(dt)
            )
        )
    ),
)

tree_st = st.recursive(
    leaf_st | st.none(),
    lambda children: st.one_of(
        st.lists(children, max_size=4),
        st.dictionaries(st.text(max_size=6), children, max_size=4),
        st.tuples(children, children),
    ),
    max_leaves=12,
)


@settings(max_examples=60, deadline=None)
@given(tree=tree_st)
def test_property_binary_roundtrip(tree):
    assert trees_equal(tree, deserialize(serialize(tree, format="binary")))


@settings(max_examples=30, deadline=None)
@given(tree=tree_st)
def test_property_flatten_unflatten_identity(tree):
    spec, leaves = flatten(tree)
    assert trees_equal(tree, unflatten(spec, leaves))


@settings(max_examples=30, deadline=None)
@given(tree=tree_st)
def test_property_binary_json_roundtrip(tree):
    assert trees_equal(tree, deserialize(serialize(tree, format="binary_json")))
