"""Core remote-function layer tests (paper §3–§4)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Deployment, FunctionConfig, RemoteFunction,
                        data_captures, rebind, reflect_captures, remote,
                        stable_name)
from repro.core.naming import canonicalize_jaxpr_text, mangle


# ------------------------------------------------------------- reflection ---

def make_closure(n, scale):
    def task(x):
        return jnp.sum(x * scale) + n
    return task


def test_reflect_captures_reads_cells():
    t = make_closure(7, 2.0)
    caps = reflect_captures(t)
    assert caps == {"n": 7, "scale": 2.0}


def test_rebind_replaces_captures():
    t = make_closure(7, 2.0)
    t2 = rebind(t, {"n": 100, "scale": 1.0})
    x = jnp.ones(3)
    assert float(t2(x)) == pytest.approx(103.0)
    # original untouched (value semantics, like serialized C++ captures)
    assert float(t(x)) == pytest.approx(13.0)


def test_rebind_partial_keeps_code_captures():
    def helper(x):
        return x * 3

    def outer():
        h = helper

        def task(x):
            return h(x) + k
        k = 5
        return task

    t = outer()
    caps = data_captures(t)
    assert set(caps) == {"k"}          # helper is a code capture, not data
    t2 = rebind(t, {"k": 10})
    assert float(t2(jnp.float32(2))) == pytest.approx(16.0)


# ------------------------------------------------------------------ naming --

def test_stable_name_deterministic_across_instances():
    a = make_closure(7, 2.0)
    b = make_closure(7, 2.0)   # distinct closure objects, same code
    x = jnp.zeros((4,), jnp.float32)
    na = stable_name(a, x)
    nb = stable_name(b, x)
    assert na == nb
    assert na.startswith("_ZRF")


def test_stable_name_changes_with_code():
    x = jnp.zeros((4,), jnp.float32)
    n1 = stable_name(lambda v: jnp.sum(v), x, human_name="f")
    n2 = stable_name(lambda v: jnp.prod(v), x, human_name="f")
    assert n1 != n2


def test_stable_name_changes_with_shape():
    f = lambda v: jnp.sum(v)  # noqa: E731
    n1 = stable_name(f, jnp.zeros((4,), jnp.float32))
    n2 = stable_name(f, jnp.zeros((8,), jnp.float32))
    assert n1 != n2


def test_canonicalization_strips_incidental_detail():
    t1 = canonicalize_jaxpr_text("a:f32[4] <function f at 0xdeadbeef>  /tmp/x.py:12")
    t2 = canonicalize_jaxpr_text("a:f32[4] <function f at 0xcafebabe> /home/y.py:99")
    assert t1 == t2


def test_mangle_is_cloud_safe():
    n = mangle("my task!! με unicode", "ab" * 32)
    assert all(c.isalnum() or c == "_" for c in n)


# ------------------------------------------------------------- deployment ---

def test_deploy_and_invoke_roundtrip():
    dep = Deployment()
    n = 1000

    @remote
    def estimate(x):
        return jnp.mean(x) * n

    d = dep.deploy(estimate, jnp.arange(8, dtype=jnp.float32))
    payload = d.bridge.pack((jnp.arange(8, dtype=jnp.float32),), {},
                            data_captures(estimate.fn))
    blob, stats = d.bridge.entry(payload)
    out = d.bridge.unpack_result(blob)
    assert float(np.asarray(out)) == pytest.approx(3500.0)
    assert d.bridge.kind == "aot_xla"
    assert stats.total_s > 0
    assert d.bridge.last_stats.total_s == stats.total_s


def test_deploy_dedup_no_recompile():
    dep = Deployment()
    x = jnp.ones((16,), jnp.float32)

    def task(v):
        return v * 2

    dep.deploy(task, x)
    assert dep.compile_count == 1
    dep.deploy(task, x)                 # unchanged → cache hit
    assert dep.compile_count == 1
    assert dep.cache_hits == 1

    def task2(v):
        return v * 3                    # code change → redeploy

    dep.deploy(task2, x)
    assert dep.compile_count == 2


def test_deploy_generic_worker_fallback():
    """Non-jax python tasks run via the generic-worker path (Lithops-style)."""
    dep = Deployment()

    def pytask(n):
        return sum(i * i for i in range(n))

    rf = RemoteFunction(pytask, jax_traceable=False)
    d = dep.deploy(rf, 10)
    blob, _ = d.bridge.entry(d.bridge.pack((10,), {}, {}))
    assert d.bridge.unpack_result(blob) == 285
    assert d.bridge.kind == "generic_worker"


def test_manifest_persists(tmp_path):
    mpath = str(tmp_path / "manifest.json")
    dep = Deployment(manifest_path=mpath)
    cfg = FunctionConfig().with_memory(512).with_ephemeral_storage(64)
    dep.deploy(RemoteFunction(lambda x: x + 1, name="inc", config=cfg),
               jnp.zeros((4,)))
    dep2 = Deployment(manifest_path=mpath)      # fresh process analogue
    assert len(dep2.manifest) == 1
    (entry,) = dep2.manifest.entries.values()
    assert entry.human_name == "inc"
    assert entry.config.memory_mb == 512
    assert entry.config.ephemeral_mb == 64
    assert entry.kind == "aot_xla"


def test_entry_stats_are_per_invocation():
    """Concurrent entries of one bridge must not share accounting: stats
    travel with the return value, not through a mutable attribute."""
    import threading
    import time

    dep = Deployment()

    def sleepy(s):
        time.sleep(s)
        return s

    d = dep.deploy(RemoteFunction(sleepy, jax_traceable=False), 0.01)
    out = {}

    def call(s):
        _, stats = d.bridge.entry(d.bridge.pack((s,), {}, {}))
        out[s] = stats.compute_s

    ts = [threading.Thread(target=call, args=(s,)) for s in (0.05, 0.3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # the fast call must see its own ~0.05 s, not the slow sibling's ~0.3 s
    assert out[0.05] < 0.2 < out[0.3]


def test_config_fluent_api_matches_paper_listing():
    cfg = (FunctionConfig()
           .with_memory(512)
           .with_ephemeral_storage(64))
    assert cfg.memory_mb == 512 and cfg.ephemeral_mb == 64
    assert cfg.memory_gb == 0.5


def test_captures_travel_in_payload():
    dep = Deployment()
    scale = np.float32(4.0)

    def task(x):
        return x * scale

    d = dep.deploy(task, jnp.ones((4,), jnp.float32))
    # invoke with *different* capture values — payload carries state
    blob, _ = d.bridge.entry(
        d.bridge.pack((jnp.ones((4,), jnp.float32),), {},
                      {"scale": np.float32(9.0)}))
    out = d.bridge.unpack_result(blob)
    assert np.allclose(np.asarray(out), 9.0)
