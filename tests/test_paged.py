"""Paged KV arena (ISSUE 7): block-table decode attention, the radix
prefix index, host-side block accounting, chunked prefill, and the paged
composition-invariance matrix — paged serving (radix sharing, chunked
prefill, block-table decode) must produce bit-identical greedy tokens to
solo wave decode, with NO wave fallback for prompts above prompt_cap."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from conftest import FAMILY_ARCHS, make_ragged_requests, solo_reference
from repro.cloud import Session
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.decode_attention.ops import decode_attention_paged
from repro.models.api import PagedArena, paged_init_pool, paged_supported
from repro.runtime import state
from repro.runtime.radix import RadixIndex
from repro.runtime.server import LMServer, Request
from repro.serving import ContinuousBatcher, run_continuous

RNG = np.random.default_rng(11)


@pytest.fixture(autouse=True)
def _clean_state_registry():
    yield
    for h in list(state.stats()["handles"]):
        state.release(h)


# ------------------------------------------------------------ radix index --

def test_radix_match_is_block_aligned():
    """Only whole blocks match: a 7-token prompt over bs=4 has one
    indexable block; the ragged tail never enters the index."""
    ix = RadixIndex(4)
    stored = ix.insert([1, 2, 3, 4, 5, 6, 7], ["b0"])
    assert stored == ["b0"] and ix.tokens == 4
    n, payloads = ix.match([1, 2, 3, 4, 5, 6, 7])
    assert (n, payloads) == (4, ["b0"])
    # agreeing on 3 of 4 tokens is no match at all
    assert ix.match([1, 2, 3, 9]) == (0, [])


def test_radix_divergence_splits_runs_at_block_boundaries():
    """Two prompts sharing their first block: the insert that diverges
    mid-run splits the node exactly at the block boundary, so the shared
    head stays one run with one payload per block."""
    ix = RadixIndex(2)
    ix.insert([1, 2, 3, 4, 5, 6], ["a", "b", "c"])
    assert ix.n_nodes == 1                       # one compressed run
    ix.insert([1, 2, 9, 9], ["a", "d"])
    # split: shared run [1,2] + two tails
    assert ix.n_nodes == 3
    assert ix.match([1, 2, 3, 4, 5, 6]) == (6, ["a", "b", "c"])
    assert ix.match([1, 2, 9, 9]) == (4, ["a", "d"])
    # partial hit: longest shared block-aligned prefix only
    n, payloads = ix.match([1, 2, 3, 4, 7, 7])
    assert (n, payloads) == (4, ["a", "b"])


def test_radix_insert_overwrite_replaces_in_place():
    ix = RadixIndex(2)
    ix.insert([5, 6, 7, 8], [0, 0])
    assert ix.insert([5, 6, 7, 8], [1, 1]) == []          # already present
    assert ix.match([5, 6, 7, 8]) == (4, [0, 0])
    ix.insert([5, 6, 7, 8], [2, 2], overwrite=True)
    assert ix.match([5, 6, 7, 8]) == (4, [2, 2])


def test_radix_lru_eviction_returns_payloads_oldest_first():
    ix = RadixIndex(2, budget_tokens=8)
    ix.insert([1, 1, 1, 1], ["old0", "old1"])
    ix.insert([2, 2, 2, 2], ["mid0", "mid1"])
    ix.match([1, 1, 1, 1])                       # renew the first run
    ix.insert([3, 3, 3, 3], ["new0", "new1"])    # 12 tokens > budget 8
    dropped = ix.evict()
    assert dropped == ["mid0", "mid1"]           # LRU, not insertion order
    assert ix.tokens == 8
    assert ix.match([1, 1, 1, 1])[0] == 4        # renewed run survived
    assert ix.match([2, 2, 2, 2])[0] == 0


def test_radix_eviction_never_frees_live_referenced_blocks():
    """The index holds its OWN reference per stored block; eviction hands
    payloads back and only refcount-zero actually frees — a block a live
    row also references survives its index eviction."""
    pa = PagedArena(batch=2, blocks=8, table_width=4, block_size=2)
    ix = RadixIndex(2, budget_tokens=4)
    # row 0 prefills [1,2,3,4]: two blocks, then the index adopts a ref
    b0, b1 = pa.alloc(), pa.alloc()
    pa.adopt(0, [b0, b1], 4)
    pa.live[0] = True
    pa.ref_inc(ix.insert([1, 2, 3, 4], [b0, b1]))
    assert pa.ref[b0] == 2 and pa.ref[b1] == 2
    # pressure evicts the run from the index -> ref_dec, nothing freed
    ix.insert([9, 9, 9, 9], [0, 0])              # over budget
    freed = pa.ref_dec([i for i in ix.evict() if i != 0])
    assert freed == []                           # live row still holds them
    assert pa.ref[b0] == 1 and pa.ref[b1] == 1
    # releasing the row is what frees the physical blocks
    assert sorted(pa.release(0)) == sorted([b0, b1])
    assert pa.ref[b0] == 0 and b0 in pa.free


def test_radix_evict_blocks_pressure_path():
    ix = RadixIndex(2)
    ix.insert([1, 1, 1, 1], ["a", "b"])
    ix.insert([2, 2], ["c"])
    dropped = ix.evict_blocks(1)
    assert len(dropped) >= 1 and ix.tokens <= 4


# ----------------------------------------------------------- paged arena --

def test_paged_arena_trash_block_is_pinned():
    pa = PagedArena(batch=1, blocks=4, table_width=2, block_size=4)
    assert pa.ref[0] == 1 and 0 not in pa.free
    assert pa.occupancy()["total_blocks"] == 3   # trash block not countable


def test_paged_arena_ensure_release_roundtrip():
    pa = PagedArena(batch=2, blocks=6, table_width=3, block_size=4)
    new = pa.ensure(0, 9)                        # ceil(9/4) = 3 blocks
    assert len(new) == 3 and all(pa.ref[b] == 1 for b in new)
    assert pa.ensure(0, 12) == []                # already covered
    with pytest.raises(ValueError, match="table width"):
        pa.ensure(1, 13)                         # 4 blocks > width 3
    pa.len[0], pa.live[0] = 9, True
    occ = pa.occupancy()
    assert occ["live_tokens"] == 9 and occ["allocated_blocks"] == 3
    freed = pa.release(0)
    assert sorted(freed) == sorted(new)
    assert not pa.table[0].any() and pa.occupancy()["allocated_blocks"] == 0


def test_paged_arena_shared_blocks_free_only_at_refcount_zero():
    pa = PagedArena(batch=2, blocks=8, table_width=4, block_size=2)
    head = pa.ensure(0, 4)                       # row 0 owns two blocks
    pa.len[0], pa.live[0] = 4, True
    pa.ref_inc(head)                             # row 1 adopts the same head
    pa.adopt(1, head, 4)
    pa.live[1] = True
    assert pa.occupancy()["shared_blocks"] == 2
    assert pa.release(0) == []                   # row 1 still references
    assert sorted(pa.release(1)) == sorted(head)


def test_paged_arena_pool_exhaustion_raises():
    pa = PagedArena(batch=1, blocks=3, table_width=4, block_size=2)
    pa.alloc(), pa.alloc()
    with pytest.raises(IndexError, match="exhausted"):
        pa.alloc()


# ----------------------------------------- block-table decode attention --

def _as_pool(k, v, bs):
    """Contiguous (B,Skv,Hkv,D) caches -> block pool + table such that the
    paged gather reconstructs them exactly (block 0 = trash)."""
    b, skv, hkv, d = k.shape
    t = skv // bs
    pool_k = np.zeros((1 + b * t, bs, hkv, d), k.dtype)
    pool_v = np.zeros_like(pool_k)
    table = np.zeros((b, t), np.int32)
    for r in range(b):
        for c in range(t):
            bid = 1 + r * t + c
            pool_k[bid] = k[r, c * bs:(c + 1) * bs]
            pool_v[bid] = v[r, c * bs:(c + 1) * bs]
            table[r, c] = bid
    return jnp.asarray(pool_k), jnp.asarray(pool_v), jnp.asarray(table)


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_paged_decode_matches_contiguous(impl):
    b, skv, bs, hq, hkv, d = 3, 32, 8, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = np.asarray(RNG.normal(size=(b, skv, hkv, d)), np.float32)
    v = np.asarray(RNG.normal(size=(b, skv, hkv, d)), np.float32)
    kv_len = jnp.asarray([32, 17, 5], jnp.int32)
    pool_k, pool_v, table = _as_pool(k, v, bs)
    ref = decode_attention_ref(q, jnp.asarray(k), jnp.asarray(v), kv_len)
    out = decode_attention_paged(q, pool_k, pool_v, table, kv_len,
                                 impl=impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_bitwise_at_pow2_width():
    """The serving invariant: at a power-of-two gathered width the paged
    ref path is BITWISE the contiguous masked decode — this equality is
    why paged tokens match the left-padded solo path exactly (the engine
    enforces pow2 caps via shape_bucket)."""
    b, skv, bs, hq, hkv, d = 2, 64, 16, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = np.asarray(RNG.normal(size=(b, skv, hkv, d)), np.float32)
    v = np.asarray(RNG.normal(size=(b, skv, hkv, d)), np.float32)
    kv_len = jnp.asarray([40, 23], jnp.int32)
    pool_k, pool_v, table = _as_pool(k, v, bs)
    ref = decode_attention_ref(q, jnp.asarray(k), jnp.asarray(v), kv_len)
    out = decode_attention_paged(q, pool_k, pool_v, table, kv_len,
                                 impl="ref")
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_paged_decode_scrambled_table_and_trash_tail():
    """Physical placement must be invisible: permuting which physical
    block holds each logical column, and pointing every column past
    kv_len at the trash block, changes nothing."""
    b, skv, bs, hq, hkv, d = 2, 32, 8, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = np.asarray(RNG.normal(size=(b, skv, hkv, d)), np.float32)
    v = np.asarray(RNG.normal(size=(b, skv, hkv, d)), np.float32)
    kv_len = jnp.asarray([20, 9], jnp.int32)
    pool_k, pool_v, table = _as_pool(k, v, bs)
    # scramble: reverse the physical pool, remap the table accordingly
    perm = np.arange(pool_k.shape[0])[::-1].copy()
    perm[perm == 0], perm[0] = perm[0], 0        # keep trash at 0... swap
    inv = np.argsort(perm)
    s_pool_k = jnp.asarray(np.asarray(pool_k)[perm])
    s_pool_v = jnp.asarray(np.asarray(pool_v)[perm])
    s_table = jnp.asarray(inv[np.asarray(table)].astype(np.int32))
    base = decode_attention_paged(q, pool_k, pool_v, table, kv_len,
                                  impl="ref")
    scr = decode_attention_paged(q, s_pool_k, s_pool_v, s_table, kv_len,
                                 impl="ref")
    assert (np.asarray(base) == np.asarray(scr)).all()
    # masked tail -> trash block: also identical
    tbl = np.asarray(table).copy()
    tbl[0, 3:] = 0                               # row 0 holds 20 <= 3*8 toks
    tbl[1, 2:] = 0                               # row 1 holds 9 <= 2*8 toks
    trash = decode_attention_paged(q, pool_k, pool_v, jnp.asarray(tbl),
                                   kv_len, impl="ref")
    assert (np.asarray(trash) == np.asarray(base)).all()


@settings(max_examples=12, deadline=None)
@given(bs=st.sampled_from([4, 8, 16]), t=st.integers(1, 4),
       hkv=st.sampled_from([1, 2]), g=st.sampled_from([1, 2]),
       data=st.data())
def test_paged_decode_hypothesis(bs, t, hkv, g, data):
    """Property: for ANY ragged kv_len over any (block_size, table_width)
    geometry, block-table decode equals contiguous decode."""
    b, d, skv = 2, 16, bs * t
    lens = [data.draw(st.integers(1, skv)) for _ in range(b)]
    rng = np.random.default_rng(bs * 100 + t * 10 + hkv)
    q = jnp.asarray(rng.normal(size=(b, hkv * g, d)), jnp.float32)
    k = np.asarray(rng.normal(size=(b, skv, hkv, d)), np.float32)
    v = np.asarray(rng.normal(size=(b, skv, hkv, d)), np.float32)
    kv_len = jnp.asarray(lens, jnp.int32)
    pool_k, pool_v, table = _as_pool(k, v, bs)
    ref = decode_attention_ref(q, jnp.asarray(k), jnp.asarray(v), kv_len)
    out = decode_attention_paged(q, pool_k, pool_v, table, kv_len,
                                 impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_ragged_skv_not_block_multiple():
    """Regression: Skv that does not divide block_k pads with ZEROS (not
    garbage) — the masked tail must not poison the softmax."""
    b, skv, hq, hkv, d = 2, 40, 4, 2, 32        # 40 % 128 != 0
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), jnp.float32)
    kv_len = jnp.asarray([40, 33], jnp.int32)
    ref = decode_attention_ref(q, k, v, kv_len)
    pal = decode_attention(q, k, v, kv_len, impl="pallas_interpret",
                           block_k=128)
    assert bool(jnp.all(jnp.isfinite(pal)))
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------- paged serving invariance matrix --
# Paged admission (radix sharing + chunked prefill + block-table decode)
# must be invisible in the tokens, for an attention family (true paged
# pool) and the ssm family (silent demotion to the slot arena), inline
# and on real worker processes.

PAGED_FAMILIES = ("dense", "ssm")


@pytest.fixture(scope="module", params=PAGED_FAMILIES, ids=PAGED_FAMILIES)
def paged_family(request):
    from repro.configs import get_smoke
    from repro.models import build_model

    cfg = get_smoke(FAMILY_ARCHS[request.param]).replace(
        param_dtype="float32", compute_dtype="float32")
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    return request.param, cfg, params


@pytest.mark.parametrize("backend", ("inline", "processes"))
def test_paged_serving_is_composition_invariant(paged_family, backend):
    fam, cfg, params = paged_family
    with Session(backend, os_threads=1) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        base = make_ragged_requests(cfg)
        rng = np.random.default_rng(3)
        # duplicates -> radix block sharing; one prompt far above
        # prompt_cap=8 -> chunked prefill (budget 8 forces multi-chunk),
        # which the slot arena could only serve via solo-wave fallback
        reqs = base + [Request(prompt=list(base[0].prompt), max_new=6),
                       Request(prompt=list(base[2].prompt), max_new=3),
                       Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                                        40)), max_new=3)]
        solo = solo_reference(server, reqs)

        async def go():
            async with ContinuousBatcher(server, max_batch=3, slots=1,
                                         max_wait_ms=5, quantum=4,
                                         prompt_cap=8, paged=True,
                                         block_size=4,
                                         prefill_budget=8) as b:
                sem = asyncio.Semaphore(3)

                async def one(r):
                    async with sem:
                        return await b.submit(r)

                comps = await asyncio.gather(*[one(r) for r in reqs])
                return comps, b.stats

        comps, stats = asyncio.run(go())
        assert [c.tokens for c in comps] == solo
        assert stats.mode == "iteration"
        if paged_supported(cfg) and cfg.family != "ssm":
            # true paged pool: the 40-token prompt chunk-prefills in
            # place of the slot arena's solo-wave fallback, and the
            # duplicate prompts share physical blocks
            assert stats.wave_fallbacks == 0
            assert stats.prefix_hits >= 1
            assert stats.shared_blocks_peak > 0
            assert stats.live_tokens_peak > 0
        else:
            # ssm: paged request demotes to the slot arena untouched
            assert stats.shared_blocks_peak == 0
        server.close(prune=False)


def test_paged_requires_unified_role():
    """A paged row is a table of shared refcounted blocks — it cannot
    migrate between pools, so disaggregated roles must refuse it."""
    from collections import deque

    from repro.serving.batcher import BatcherStats, EngineLoop

    with pytest.raises(ValueError, match="unified"):
        EngineLoop(object(), index=0, queue=deque(), arrived=None,
                   stats=BatcherStats(), cpu=None, is_closed=lambda: True,
                   handoff=lambda *a: None, role="prefill", paged=True)


# --------------------------------------------------- radix fleet routing --

def test_radix_fleet_routing_is_composition_invariant():
    from repro.configs import get_smoke
    from repro.fleet import FleetRouter, run_fleet
    from repro.models import build_model

    cfg = get_smoke("smollm-360m").replace(param_dtype="float32",
                                           compute_dtype="float32")
    params, _ = build_model(cfg).init(jax.random.PRNGKey(0))
    with Session("processes", os_threads=1) as sess:
        server = LMServer(cfg, params, session=sess, max_new=8)
        base = make_ragged_requests(cfg)
        reqs = base + [Request(prompt=list(base[0].prompt), max_new=6),
                       Request(prompt=list(base[2].prompt) + [5, 9],
                               max_new=3)]
        solo = solo_reference(server, reqs)
        comps, s = run_fleet(server, reqs, n_members=2, policy="radix",
                             max_batch=3, quantum=4, prompt_cap=16,
                             paged=True, block_size=4, return_stats=True)
        assert [c.tokens for c in comps] == solo
        # the duplicate and the extended prompt radix-route to the owner
        assert s["routing"]["prefix"] >= 1
        # block tables cannot migrate between pools
        with pytest.raises(ValueError, match="disaggregate"):
            FleetRouter(server, paged=True, disaggregate=True)
        server.close(prune=False)
