"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward + one train step on CPU, asserting output
shapes and finiteness.  Also checks prefill→decode vs full-forward
consistency (the two entry points must agree on the next token)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import build_model, make_train_step
from repro.optim import AdamW

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, with_labels=False):
    batch = {}
    kt, ke, kl = jax.random.split(KEY, 3)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            ke, (B, S, cfg.d_model)).astype(jnp.bfloat16)
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    elif cfg.embeds_input:
        batch["embeds"] = jax.random.normal(
            ke, (B, S, cfg.d_model)).astype(jnp.bfloat16)
        if cfg.mrope_sections:
            batch["pos3d"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (3, B, S))
    else:
        batch["tokens"] = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    if with_labels:
        batch["labels"] = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, specs = model.init(KEY)
    # spec tree mirrors param tree
    assert set(jax.tree.structure(params).node_data()[1] or []) == \
        set(jax.tree.structure(specs).node_data()[1] or [])
    logits, _ = jax.jit(model.forward)(params, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(KEY)
    opt = AdamW(peak_lr=1e-3, warmup=2, total_steps=10)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = _batch(cfg, with_labels=True)
    params, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    leaves = jax.tree.leaves(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """Greedy argmax from (prefill S-1 tokens, decode token S-1) must equal
    argmax of the full forward's last position."""
    cfg = get_smoke(arch)
    if cfg.family == "hybrid":
        # decode recomputes conv/ssd state by a different (sequential)
        # algorithm; run in f32 so the check proves algorithmic equality
        # rather than bf16 drift across 54 recurrent layers.
        cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(KEY)
    batch = _batch(cfg)
    logits_full, _ = jax.jit(model.forward)(params, batch)

    if cfg.family == "encdec":
        pre = {"frames": batch["frames"], "tokens": batch["tokens"][:, :-1]}
    elif cfg.embeds_input:
        pre = {k: (v[:, :-1] if k == "embeds" else v[..., :-1])
               for k, v in batch.items()}
    else:
        pre = {"tokens": batch["tokens"][:, :-1]}
    _, cache = jax.jit(model.prefill)(params, pre)
    from repro.models.api import grow_cache
    cache = grow_cache(cfg, cache, S + 1)

    if cfg.embeds_input and cfg.family != "encdec":
        pytest.skip("vlm decode consumes token ids, not embeds — "
                    "consistency is covered by token-input archs")
    last_tok = batch["tokens"][:, -1:]
    logits_dec, _ = jax.jit(model.decode)(params, cache, last_tok)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits_dec), -1),
        np.argmax(np.asarray(logits_full[:, -1]), -1))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact_assignment(arch):
    """The FULL configs carry the assigned dims verbatim (never run on CPU
    — exercised via the dry-run's ShapeDtypeStruct lowering only)."""
    cfg = get_config(arch)
    expected = {
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    if arch == "phi3.5-moe-42b-a6.6b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 2)
    if arch == "dbrx-132b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (16, 4)
    if arch == "zamba2-2.7b":
        assert cfg.ssm.state_dim == 64
    if arch == "gemma-2b":
        assert cfg.head_dim == 256


def test_param_counts_plausible():
    """Analytic param counts should be in the advertised ballpark."""
    expect = {"qwen2-7b": (6e9, 9e9), "smollm-360m": (3e8, 4.5e8),
              "gemma-2b": (2e9, 3.5e9), "dbrx-132b": (1.1e11, 1.5e11),
              "zamba2-2.7b": (2.2e9, 3.2e9), "rwkv6-1.6b": (1.2e9, 2.2e9),
              "phi3.5-moe-42b-a6.6b": (3.7e10, 4.8e10)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
