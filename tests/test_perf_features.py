"""Beyond-paper perf features: int8 KV cache, sharding presets, EP config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.models.api import grow_cache
from repro.models.attention import dequantize_kv, quantize_kv
from repro.sharding import PRESETS, resolve


def test_kv_quant_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 7, 3, 16)) * 3.0, jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = dequantize_kv(q, s, jnp.float32)
    # max error is half an LSB of the per-(token,head) scale
    err = jnp.abs(back - x)
    bound = s[..., None] * 0.5 + 1e-6
    assert bool(jnp.all(err <= bound))


@pytest.mark.parametrize("arch", ["qwen2-7b", "gemma-2b"])
def test_int8_kv_decode_matches_argmax(arch):
    cfg = get_smoke(arch).replace(kv_quant="int8")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    logits, _ = jax.jit(model.forward)(params, {"tokens": toks})
    _, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :-1]})
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    cache = grow_cache(cfg, cache, S + 1)
    lgd, c2 = jax.jit(model.decode)(params, cache, toks[:, -1:])
    np.testing.assert_array_equal(
        np.argmax(np.asarray(lgd), -1),
        np.argmax(np.asarray(logits[:, -1]), -1))
    assert int(c2["idx"]) == S


def test_int8_cache_is_half_the_bytes():
    cfg = get_smoke("qwen2-7b")
    m_fp = build_model(cfg)
    m_q = build_model(cfg.replace(kv_quant="int8"))
    fp = jax.eval_shape(lambda: m_fp.init_cache(4, 128))
    q = jax.eval_shape(lambda: m_q.init_cache(4, 128))
    nbytes = lambda c: sum(  # noqa: E731
        np.prod(v.shape) * v.dtype.itemsize for v in jax.tree.leaves(c))
    # smoke head_dim=16 -> f32 scale adds 25% overhead (0.625x); the real
    # configs at head_dim=128 reach 0.52x.
    assert nbytes(q) <= 0.63 * nbytes(fp)


def test_presets_resolve():
    assert resolve("baseline") == {}
    assert resolve("flashdecode")["act_kv_seq"] == ("model",)
    assert set(PRESETS) >= {"baseline", "fulldp_zero", "seqparallel",
                            "flashdecode"}
    with pytest.raises(KeyError):
        resolve("nope")
