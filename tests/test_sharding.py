"""Sharding rules: divisibility fallback, spec resolution, and a real
multi-device equivalence check (sharded train step == single-device) run
in a subprocess so the 1-device pytest process stays untouched."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import AxisRules, shard, use_rules


def _mesh(shape=(2, 2), names=("data", "model")):
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip("needs multiple devices")
    return Mesh(np.asarray(devs[:n]).reshape(shape), names)


class _FakeMesh:
    """Stub with the two attributes AxisRules consumes — lets us test the
    16x16 resolution logic in a 1-device pytest process."""
    axis_names = ("data", "model")
    devices = np.empty((16, 16), dtype=object)


def test_spec_resolution_and_fallback():
    rules = AxisRules(_FakeMesh())
    # divisible dims resolve
    s = rules.spec(("embed", "mlp"), (64, 32))
    assert s == P("data", "model")
    # indivisible dim falls back to replicated and is recorded
    rules.fallbacks.clear()
    s = rules.spec(("heads",), (15,))
    assert s == P()
    assert rules.fallbacks and rules.fallbacks[0][0] == "heads"
    # batch over joint (pod, data): pod absent from this mesh -> data only
    s = rules.spec(("act_batch", "act_seq"), (32, 4096))
    assert s == P("data")


def test_used_axis_not_reused():
    rules = AxisRules(_FakeMesh())
    # both logical axes map to "model": second one must drop
    s = rules.spec(("experts", "mlp"), (16, 16))
    flat = [a for a in s if a is not None]
    assert flat == ["model"]


def test_shard_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shard(x, "act_batch", None) is x


# Kept deliberately tiny (1 scanned layer, 2x8 batch): the equivalence
# property is per-op resharding correctness, which does not grow with
# depth, while XLA's 4-fake-device compile time very much does (the
# 2-layer/4x16 version of this script took ~8 min; this one ~12 s).
MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_smoke
from repro.models import build_model, make_train_step
from repro.optim import AdamW
from repro.sharding import AxisRules, tree_shardings, use_rules

cfg = get_smoke("qwen2-7b").replace(n_layers=1)
model = build_model(cfg)
params, specs = model.init(jax.random.PRNGKey(0))
opt = AdamW(peak_lr=1e-3, warmup=2, total_steps=10)
opt_state = opt.init(params)
kt, kl = jax.random.split(jax.random.PRNGKey(1))
batch = {"tokens": jax.random.randint(kt, (2, 8), 0, cfg.vocab_size),
         "labels": jax.random.randint(kl, (2, 8), 0, cfg.vocab_size)}
step = make_train_step(model, opt)

# single device
p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

# 2x2 mesh with production rules
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
rules = AxisRules(mesh)
p_sh = tree_shardings(rules, params, specs)
pp = jax.device_put(params, p_sh)
oo = jax.device_put(opt_state, tree_shardings(
    rules, opt_state, opt.state_specs(specs)))
bb = {k: jax.device_put(v, rules.sharding(("act_batch", "act_seq"), v.shape))
      for k, v in batch.items()}
with use_rules(rules):
    p2, o2, m2 = jax.jit(step)(pp, oo, bb)

l1, l2 = float(m1["loss"]), float(m2["loss"])
# bf16 params + different reduction orders across device shards drift the
# loss by ~1e-3 relative (the seed's 20.3499-vs-20.3698 failure was exactly
# this); compare relative, with headroom, instead of absolute 5e-3.
rel = abs(l1 - l2) / max(abs(l1), 1e-9)
assert rel < 5e-3, (l1, l2, rel)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-2, d
print("SHARDED_EQUIV_OK", l1, l2, rel, d)
"""


def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # pin cpu: without it jax probes for TPUs for 60+ s before giving up
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_EQUIV_OK" in out.stdout, out.stderr[-2000:]


def test_moe_sharded_matches_single_device():
    """Both MoE impls (replicated-psum and expert-parallel all_to_all)
    must agree with the unsharded reference on a 2x2 mesh."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.models.moe import moe_apply, moe_init
from repro.sharding import AxisRules, use_rules

p, s = moe_init(jax.random.PRNGKey(0), 16, 32, 4, "swiglu", jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
f = lambda p, x: moe_apply(p, x, n_experts=4, top_k=2,
                           capacity_factor=8.0, act="swiglu")
y1, m1 = jax.jit(f)(p, x)

mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2), ("data", "model"))
rules = AxisRules(mesh)
with use_rules(rules):
    y2, m2 = jax.jit(f)(p, x)
    y3, m3 = jax.jit(lambda p, x: moe_apply(
        p, x, n_experts=4, top_k=2, capacity_factor=8.0, act="swiglu",
        impl="ep_a2a"))(p, x)
a1, a2, a3 = (np.asarray(y) for y in (y1, y2, y3))   # host: sharded vs not
d = float(np.max(np.abs(a1 - a2)))
d3 = float(np.max(np.abs(a1 - a3)))
assert d < 1e-4, d
assert d3 < 1e-4, d3
print("MOE_EP_OK", d, d3)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    # pin cpu: without it jax probes for TPUs for 60+ s before giving up
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MOE_EP_OK" in out.stdout, out.stderr[-2000:]
