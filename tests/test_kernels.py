"""Per-kernel allclose sweeps: Pallas (interpret=True) vs the jnp oracle,
across shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.flash_attention import attention, attention_ref
from repro.kernels.flash_attention.ref import attention_xla
from repro.kernels.mamba2_ssd import ssd, ssd_scan_ref
from repro.kernels.mamba2_ssd.ref import ssd_decode_ref
from repro.kernels.rwkv6_wkv import wkv6, wkv6_scan_ref
from repro.kernels.rwkv6_wkv.ref import wkv6_decode_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- flash attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,hq,hkv,d,causal,window", [
    (1, 64, 64, 4, 4, 32, True, 0),       # MHA causal
    (2, 80, 80, 6, 2, 64, True, 0),       # GQA, non-multiple seq
    (2, 48, 48, 4, 1, 128, True, 0),      # MQA, big head
    (1, 64, 64, 4, 2, 32, False, 0),      # bidirectional
    (2, 96, 96, 4, 2, 32, True, 24),      # sliding window
    (1, 33, 33, 2, 2, 16, True, 0),       # odd seq
])
def test_flash_attention_sweep(b, sq, skv, hq, hkv, d, causal, window,
                               dtype):
    q = jnp.asarray(RNG.normal(size=(b, sq, hq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    pal = attention(q, k, v, causal=causal, window=window,
                    impl="pallas_interpret", block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_q_offset():
    """Chunked prefill: q block continuing an existing kv timeline."""
    q = jnp.asarray(RNG.normal(size=(1, 16, 2, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 48, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 48, 2, 32)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True, q_offset=32)
    pal = attention(q, k, v, causal=True, q_offset=32,
                    impl="pallas_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_kv_start_masks_left_pad_on_all_impls():
    """Per-row kv_start (ragged-batch left padding): XLA and Pallas paths
    must agree with the oracle, and an explicit slice of the unpadded
    problem must agree with the masked padded one."""
    b, s, hq, hkv, d = 3, 64, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    kv_start = jnp.asarray([0, 17, 40], jnp.int32)
    ref = attention_ref(q, k, v, causal=True, kv_start=kv_start)
    for impl, kw in (("xla", {}), ("pallas_interpret",
                                   {"block_q": 32, "block_k": 32})):
        out = attention(q, k, v, causal=True, kv_start=kv_start,
                        impl=impl, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5, err_msg=impl)
    # row 2: the masked suffix must equal attention over the suffix alone
    st_ = 40
    solo = attention_ref(q[2:, st_:], k[2:, st_:], v[2:, st_:], causal=True)
    np.testing.assert_allclose(np.asarray(ref[2, st_:]),
                               np.asarray(solo[0]), rtol=2e-5, atol=2e-5)


def test_flash_attention_fully_masked_rows_finite():
    """kv_start == Skv (a filler row): output must be finite on every
    impl, never NaN from an all-masked softmax row."""
    b, s, hq, hkv, d = 2, 32, 2, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    kv_start = jnp.asarray([s, 5], jnp.int32)     # row 0 fully masked
    for impl, kw in (("ref", {}), ("xla", {}),
                     ("pallas_interpret", {"block_q": 16, "block_k": 16})):
        out = attention(q, k, v, causal=True, kv_start=kv_start,
                        impl=impl, **kw)
        assert bool(jnp.all(jnp.isfinite(out))), impl


def test_decode_attention_kv_start_matches_unpadded():
    """Decode over a cache with left-pad junk below kv_start must equal
    decode over the compacted cache, ref and Pallas."""
    b, skv, hq, hkv, d = 2, 64, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), jnp.float32)
    kv_start = jnp.asarray([0, 24], jnp.int32)
    kv_len = jnp.asarray([50, 64], jnp.int32)
    ref = decode_attention_ref(q, k, v, kv_len, kv_start=kv_start)
    pal = decode_attention(q, k, v, kv_len, kv_start=kv_start,
                           impl="pallas_interpret", block_k=128)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # row 1 vs the compacted (junk removed) cache
    solo = decode_attention_ref(q[1:], k[1:, 24:], v[1:, 24:],
                                jnp.asarray([40], jnp.int32))
    np.testing.assert_allclose(np.asarray(ref[1]), np.asarray(solo[0]),
                               rtol=2e-5, atol=2e-5)


def test_attention_xla_chunked_matches_oracle():
    q = jnp.asarray(RNG.normal(size=(2, 100, 6, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 100, 3, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 100, 3, 32)), jnp.float32)
    for causal, window in [(True, 0), (False, 0), (True, 13)]:
        ref = attention_ref(q, k, v, causal=causal, window=window)
        out = attention_xla(q, k, v, causal=causal, window=window,
                            block_q=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(8, 70), hkv=st.sampled_from([1, 2, 3]),
       g=st.sampled_from([1, 2, 4]), d=st.sampled_from([16, 32]))
def test_flash_attention_hypothesis(sq, hkv, g, d):
    q = jnp.asarray(RNG.normal(size=(1, sq, hkv * g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, sq, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, sq, hkv, d)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    pal = attention(q, k, v, causal=True, impl="pallas_interpret",
                    block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref), rtol=3e-5,
                               atol=3e-5)


# ------------------------------------------------------ decode attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,skv,hq,hkv,d,window", [
    (2, 300, 4, 2, 64, 0),
    (1, 128, 8, 1, 32, 0),        # MQA
    (3, 257, 6, 6, 32, 0),        # MHA odd cache
    (2, 300, 4, 2, 64, 64),       # windowed
])
def test_decode_attention_sweep(b, skv, hq, hkv, d, window, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, skv, hkv, d)), dtype)
    kv_len = jnp.asarray(RNG.integers(window + 1 if window else 1, skv + 1,
                                      size=(b,)), jnp.int32)
    ref = decode_attention_ref(q, k, v, kv_len, window=window)
    pal = decode_attention(q, k, v, kv_len, window=window,
                           impl="pallas_interpret", block_k=128)
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_matches_full_attention_last_row():
    """decode(q_last | cache) == full-causal attention's last row."""
    b, s, hq, hkv, d = 2, 40, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    full = attention_ref(q, k, v, causal=True)
    dec = decode_attention_ref(q[:, -1], k, v,
                               jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- SSD ----

@pytest.mark.parametrize("impl", ["chunked", "pallas_interpret"])
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 96, 4, 8, 2, 16, 32),
    (1, 64, 2, 16, 1, 8, 16),
    (2, 100, 4, 8, 1, 16, 32),      # needs padding
])
def test_ssd_sweep(b, s, h, p, g, n, chunk, impl):
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    y0, h0 = ssd_scan_ref(x, dt, A, Bm, Cm)
    y1, h1 = ssd(x, dt, A, Bm, Cm, chunk=chunk, impl=impl)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), rtol=2e-4,
                               atol=2e-4)


def test_ssd_initial_state_and_decode_consistency():
    """Chunked scan with h0 == continuing the sequence; decode == 1-step."""
    b, s, h, p, g, n = 1, 64, 2, 8, 1, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    yf, hf = ssd_scan_ref(x, dt, A, Bm, Cm)
    # split at 32: scan first half, then chunked-with-state second half
    y1, h1 = ssd_scan_ref(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32])
    y2, h2 = ssd(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                 h0=h1, chunk=16, impl="chunked")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(yf[:, 32:]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(hf), rtol=2e-4,
                               atol=2e-4)
    # single-token decode continues exactly
    y3, h3 = ssd_decode_ref(x[:, 32, :, :], dt[:, 32], A, Bm[:, 32],
                            Cm[:, 32], h1)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(yf[:, 32]),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(4, 80), chunk=st.sampled_from([8, 16, 32]),
       h=st.sampled_from([1, 2, 4]))
def test_ssd_hypothesis(s, chunk, h):
    b, p, g, n = 1, 4, 1, 4
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(b, s, g, n)), jnp.float32)
    y0, h0 = ssd_scan_ref(x, dt, A, Bm, Cm)
    y1, h1 = ssd(x, dt, A, Bm, Cm, chunk=chunk, impl="chunked")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=3e-4,
                               atol=3e-4)


# ---------------------------------------------------------------- WKV6 ----

@pytest.mark.parametrize("impl", ["chunked", "pallas_interpret"])
@pytest.mark.parametrize("b,s,h,k,chunk", [
    (2, 96, 4, 8, 32),
    (1, 64, 2, 16, 16),
    (2, 70, 4, 8, 32),             # needs padding
])
def test_wkv6_sweep(b, s, h, k, chunk, impl):
    r = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    logw = jnp.asarray(-RNG.uniform(0.01, 1.0, size=(b, s, h, k)),
                       jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, k)), jnp.float32)
    o0, s0 = wkv6_scan_ref(r, kk, v, logw, u)
    o1, s1 = wkv6(r, kk, v, logw, u, chunk=chunk, impl=impl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o0), rtol=5e-4,
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0), rtol=5e-4,
                               atol=5e-4)


def test_wkv6_decode_consistency():
    b, s, h, k = 1, 33, 2, 8
    r = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    logw = jnp.asarray(-RNG.uniform(0.01, 1.0, size=(b, s, h, k)),
                       jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, k)), jnp.float32)
    of, sf = wkv6_scan_ref(r, kk, v, logw, u)
    o1, s1 = wkv6(r[:, :-1], kk[:, :-1], v[:, :-1], logw[:, :-1], u,
                  chunk=8, impl="chunked")
    o2, s2 = wkv6_decode_ref(r[:, -1], kk[:, -1], v[:, -1], logw[:, -1],
                             u, s1)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(of[:, -1]),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sf), rtol=5e-4,
                               atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(4, 70), chunk=st.sampled_from([8, 16, 32]))
def test_wkv6_hypothesis(s, chunk):
    b, h, k = 1, 2, 4
    r = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    kk = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, k)), jnp.float32)
    logw = jnp.asarray(-RNG.uniform(0.01, 2.0, size=(b, s, h, k)),
                       jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, k)), jnp.float32)
    o0, s0 = wkv6_scan_ref(r, kk, v, logw, u)
    o1, s1 = wkv6(r, kk, v, logw, u, chunk=chunk, impl="chunked")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o0), rtol=1e-3,
                               atol=1e-3)
