"""End-to-end LM training driver with fault tolerance.

    PYTHONPATH=src python examples/train_lm.py            # CPU-sized
    PYTHONPATH=src python examples/train_lm.py --arch gemma-2b --full ...

Trains an assigned-architecture config on the deterministic synthetic
stream for a few hundred steps, checkpointing asynchronously, and then
PROVES the fault-tolerance path by injecting a preemption and showing the
restarted run converge to the same loss.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import get_config, get_smoke        # noqa: E402
from repro.runtime import train                        # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (needs accelerators)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    with tempfile.TemporaryDirectory() as ckpt:
        rep = train(cfg, steps=args.steps, global_batch=args.batch,
                    seq_len=args.seq, peak_lr=5e-3, ckpt_dir=ckpt,
                    ckpt_every=max(10, args.steps // 5),
                    fail_at={args.steps // 2},       # injected preemption
                    on_step=lambda s, m: (
                        s % 20 == 0 and print(
                            f"  step {s:4d} loss {float(m['loss']):.4f}")))
        print(f"first loss {rep.losses[0]:.4f} -> final "
              f"{rep.final_loss:.4f}  ({rep.restarts} restart(s), "
              f"resumed from {rep.restored_from})")
        assert rep.final_loss < rep.losses[0]


if __name__ == "__main__":
    main()
