"""Quickstart — the paper's PI example (Fig 6) on the session API.

    PYTHONPATH=src python examples/quickstart.py [backend ...]

One source, many targets: the same functions run locally, on real threads,
synchronously inline, in real worker *processes*, or behind an HTTP worker
— only the ``cloud.Session(backend)`` line (here: argv) changes.  A
jax-traceable task is deployed as a serverless function (AOT-compiled
entry point, content-addressed name, binary payloads), fanned out fork-join
style, and billed in GB-seconds.  On the out-of-process backends
(``processes``/``http``) the payload genuinely crosses a process/socket
boundary: workers rebuild the entry points from the manifest (script-
defined functions therefore import what they use inside the body), cold
starts are real AOT compiles, and ``http`` records carry *measured*
client-observed latency.
"""
import sys

sys.path.insert(0, "src")

from repro import cloud                                 # noqa: E402
from repro.apps import compute_pi                       # noqa: E402


def run(backend: str) -> None:
    print(f"\n=== backend: {backend} ===")
    with cloud.Session(backend) as sess:
        # ---- high-level: the paper's compute_pi workflow on this session
        pi, _ = compute_pi(n=1_000_000, np_=32, session=sess)
        print(f"pi ≈ {pi:.5f}")

        # ---- low-level: define and bind your own serverless function
        # (body-local import: script functions must be self-contained to
        #  run in fresh worker processes — see runtime/worker_host.py)
        @sess.remote(memory_mb=512, serializer="binary")
        def square_sum(n):
            import jax.numpy as jnp
            x = jnp.arange(n, dtype=jnp.float32)
            return jnp.sum(x * x)

        # single-source: the handle is still a plain local callable
        print("local call:", float(square_sum(1000)))

        # streaming fork-join: as_completed yields futures as they finish
        futs = [square_sum.submit(1000 * (i + 1)) for i in range(8)]
        print("results:", [float(f.result()) for f in cloud.as_completed(futs)])

        # gather resolves the same futures in submit order
        ordered = cloud.gather(futs)
        print("gathered (submit order):", [float(r) for r in ordered])

        # per-call overrides chain off the handle (call > handle > function)
        big = square_sum.options(memory_mb=2048).submit(1_000_000)
        print("with 2 GiB:", float(big.result()),
              f"billed at {big.record.memory_gb:.0f} GB")

        print("cost:", sess.cost.summary())
        print("deployments:", sess.deployment.compile_count,
              "cache hits:", sess.deployment.cache_hits)
        print("manifest entries:",
              sorted({e.human_name
                      for e in sess.deployment.manifest.entries.values()}))


def main():
    # identical application code on every backend — the single-source claim
    for backend in (sys.argv[1:] or ("threads", "inline")):
        run(backend)


if __name__ == "__main__":
    main()
