"""Quickstart — the paper's PI example (Fig 6), start to finish.

    PYTHONPATH=src python examples/quickstart.py

A jax-traceable task is deployed as a serverless function (AOT-compiled
entry point, content-addressed name, binary payloads), dispatched 32 times
fork-join style, and billed in GB-seconds.
"""
import sys

sys.path.insert(0, "src")

from repro.apps import compute_pi                       # noqa: E402
from repro.core import FunctionConfig, remote           # noqa: E402
from repro.dispatch import Dispatcher                   # noqa: E402


def main():
    # ---- high-level: the paper's compute_pi workflow
    pi, inst = compute_pi(n=1_000_000, np_=32)
    print(f"pi ≈ {pi:.5f}")
    print("cost:", inst.cost.summary())

    # ---- low-level: define your own serverless function
    d = Dispatcher()
    inst = d.create_instance()

    @remote(config=FunctionConfig(memory_mb=512, serializer="binary"))
    def square_sum(n):
        import jax.numpy as jnp
        x = jnp.arange(n, dtype=jnp.float32)
        return jnp.sum(x * x)

    futs = [inst.dispatch(square_sum, 1000 * (i + 1)) for i in range(8)]
    inst.wait()
    print("results:", [float(f.result()) for f in futs])
    print("deployments:", d.deployment.compile_count,
          "cache hits:", d.deployment.cache_hits)
    print("manifest entries:",
          [e.human_name for e in d.deployment.manifest.entries.values()])
    d.shutdown()


if __name__ == "__main__":
    main()
