"""Tiled Monte-Carlo raytracer offload (paper Figs 1/14).

    PYTHONPATH=src python examples/raytracer.py [--size 64] [--spp 2] \
        [--backend threads|inline|sim-aws]

Renders the same random sphere scene serially and as per-tile serverless
tasks; writes a PPM you can actually look at, and prints the Fig 14-style
cost comparison across tile sizes.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                       # noqa: E402

from repro.apps import random_scene, render_serial, render_serverless  # noqa: E402
from repro.cloud import Session, available_backends      # noqa: E402


def write_ppm(path, img):
    h, w, _ = img.shape
    with open(path, "wb") as f:
        f.write(f"P6 {w} {h} 255\n".encode())
        f.write((np.clip(img, 0, 1) * 255).astype(np.uint8).tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--spp", type=int, default=2)
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    args = ap.parse_args()

    scene = random_scene(width=args.size, height=args.size, n_spheres=24)
    t0 = time.perf_counter()
    img = render_serial(scene, spp=args.spp)
    print(f"serial: {time.perf_counter()-t0:.2f}s")
    write_ppm("render_serial.ppm", img)

    for tile in (args.size // 2, args.size // 4):
        t0 = time.perf_counter()
        with Session(args.backend) as sess:
            img_s, _ = render_serverless(scene, tile=tile, spp=args.spp,
                                         session=sess)
            wall = time.perf_counter() - t0
            print(f"tile {tile}x{tile}: {sess.cost.invocations} tasks, "
                  f"wall {wall:.2f}s (1 core), modeled cloud makespan "
                  f"{sess.modeled_makespan_ms()/1e3:.2f}s, "
                  f"bill {sess.cost.gb_seconds:.2f} GB-s")
        write_ppm(f"render_tile{tile}.ppm", img_s)
    print("wrote render_*.ppm")


if __name__ == "__main__":
    main()
