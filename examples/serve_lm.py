"""Serverless LM serving — batched generation requests as offloaded tasks.

    PYTHONPATH=src python examples/serve_lm.py \
        [--requests 12 --max-new 8] [--backend processes|http|...] \
        [--mode waves|continuous]

Every decode batch is one stateless serverless invocation (prefill +
greedy decode loop, AOT-compiled entry point); the dispatcher provides
retry/hedging and the GB-seconds bill per request.  ``--mode continuous``
runs the same requests through the asyncio continuous batcher instead of
fixed waves — same results, serving-shaped scheduling.  On backends with
worker-resident state (threads/inline/processes/http*) the batcher runs
*iteration-level*: the KV cache stays resident on the worker across
invocations, requests join a running decode batch every few steps, and
repeated prompts skip prefill via the prompt-prefix cache (ISSUE 5).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro.cloud import Session, available_backends     # noqa: E402
from repro.configs import get_smoke                     # noqa: E402
from repro.models import build_model                    # noqa: E402
from repro.runtime import LMServer, Request             # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--wave", type=int, default=4)
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    ap.add_argument("--mode", default="waves",
                    choices=("waves", "continuous"))
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    session = Session(args.backend)
    server = LMServer(cfg, params, session=session, max_new=args.max_new)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                             args.prompt_len)),
                    max_new=args.max_new) for _ in range(args.requests)]
    t0 = time.perf_counter()
    if args.mode == "continuous":
        from repro.serving import run_continuous
        comps = run_continuous(server, reqs, concurrency=args.requests,
                               max_batch=args.wave, slots=2)
    else:
        comps = server.serve(reqs, wave_size=args.wave)
    wall = time.perf_counter() - t0
    for i, c in enumerate(comps[:4]):
        print(f"req {i}: {c.tokens}  ({c.cost_gb_s:.4f} GB-s)")
    print(f"{len(comps)} requests in {wall:.2f}s ({args.mode} on "
          f"{args.backend}); bill:", server.cost_report.summary())
    server.close()
    session.close()


if __name__ == "__main__":
    main()
