"""N-Queens with prefix-task offload (paper §5.2, Figs 12/13).

    PYTHONPATH=src python examples/nqueens.py [--n 10] [--p 2] \
        [--backend threads|inline|sim-aws]

Shows the decomposition (longer prefix -> more, smaller, heterogeneous
tasks), the exactness of the parallel count, and the pay-per-use bill —
on any registered backend, with no solver-code changes.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.apps import KNOWN, prefixes, solve_serial, solve_serverless  # noqa: E402
from repro.cloud import Session, available_backends                     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--backend", default="threads",
                    choices=available_backends())
    args = ap.parse_args()

    t0 = time.perf_counter()
    serial = solve_serial(args.n)
    t_serial = time.perf_counter() - t0
    print(f"N={args.n}: {serial} solutions "
          f"(known: {KNOWN.get(args.n, '?')}), serial {t_serial:.2f}s")

    for p in (1, args.p):
        t0 = time.perf_counter()
        with Session(args.backend) as sess:
            total, ntasks, _ = solve_serverless(args.n, p, session=sess)
            wall = time.perf_counter() - t0
            assert total == serial
            print(f"prefix={p}: {ntasks} tasks, wall {wall:.2f}s "
                  f"(1-core container; modeled cloud makespan "
                  f"{sess.modeled_makespan_ms():.0f} ms), "
                  f"bill {sess.cost.gb_seconds:.2f} GB-s "
                  f"= ${sess.cost.dollars:.6f}")


if __name__ == "__main__":
    main()
