"""§Perf hillclimb: phi3.5-moe train_4k — expert-parallel all_to_all MoE."""
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.perf_iter import run_variants
from repro.configs.base import MoEConfig

EP = MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25, impl="ep")

run_variants("phi3.5-moe-42b-a6.6b", "train_4k", [
    {"name": "ep_a2a",
     "hypothesis": ("MoE combine is a psum of (65536, 4096) bf16 per layer "
                    "per direction (~92 GiB of the 302 GiB all-reduce wire); "
                    "token dispatch via two all_to_alls of capacity buffers "
                    "(tokens seq-sharded over model) cuts MoE wire ~8x for "
                    "top-2/16-way and de-replicates router+pack compute. "
                    "Predict t_collective 1.72 -> ~1.0 (attention psums "
                    "remain), flops frac up slightly."),
     "cfg": {"moe": EP}, "rules": {}},
    {"name": "ep_a2a_sp",
     "hypothesis": ("Remaining wire is attention-block activation psums. "
                    "Megatron sequence-parallelism: keep inter-block "
                    "activations seq-sharded over model (act_seq->model), "
                    "turning each all-reduce into reduce-scatter+all-gather "
                    "(same wire, half latency exposure, 16x activation "
                    "memory saving) -> temp GiB should drop sharply; wire "
                    "roughly neutral vs ep_a2a."),
     "cfg": {"moe": EP},
     "rules": {"act_seq": ("model",), "act_embed": None}},
], include_baseline=False)
