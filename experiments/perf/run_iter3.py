"""Third §Perf iteration across the three hillclimb cells."""
import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.perf_iter import run_variants
from repro.configs.base import MoEConfig

EP = MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25, impl="ep")

# qwen1.5-4b decode: int8 KV on top of seq-sharded cache
run_variants("qwen1.5-4b", "decode_32k", [
    {"name": "kvseq_model_int8kv",
     "hypothesis": ("Iteration 2. After seq-sharding, args 6.39 GiB/dev is "
                    "~all KV cache (bf16). int8 quantization with per-"
                    "(token,head) scales halves cache bytes: args -> ~3.3 "
                    "GiB, t_memory 1.16 -> ~0.7s. Greedy decode argmax "
                    "verified unchanged on the smoke config."),
     "cfg": {"kv_quant": "int8"},
     "rules": {"act_kv_seq": ("model",)}},
], include_baseline=False)

# zamba2 train: remat full on top of full-DP
run_variants("zamba2-2.7b", "train_4k", [
    {"name": "fulldp_zero_rematfull",
     "hypothesis": ("Iteration 2. After full-DP the bound is memory "
                    "(t_mem 4.45s, temp 125 GiB/dev >> 16 GiB HBM). "
                    "remat=full recomputes block activations in backward: "
                    "predict temp -> ~3x lower, t_memory down, t_compute "
                    "up ~30% (recompute) — a net win while memory-bound."),
     "cfg": {"remat": "full"},
     "rules": {"act_batch": ("data", "model"), "act_inner": None,
               "act_heads": None, "act_kv_heads": None, "act_mlp": None,
               "act_vocab": None, "inner": None, "heads": None,
               "kv_heads": None, "mlp": None, "vocab": None}},
], include_baseline=False)

# phi3.5 train: remat full on top of ep_a2a + SP
run_variants("phi3.5-moe-42b-a6.6b", "train_4k", [
    {"name": "ep_a2a_sp_rematfull",
     "hypothesis": ("Iteration 3. Memory still dominates (4.89s, temp 96 "
                    "GiB). remat=full trades recompute for activation "
                    "memory: predict temp -> ~40 GiB, t_memory -> ~3s, "
                    "t_compute 1.27 -> ~1.7s. Net win while memory-bound."),
     "cfg": {"moe": EP, "remat": "full"},
     "rules": {"act_seq": ("model",), "act_embed": None}},
], include_baseline=False)
