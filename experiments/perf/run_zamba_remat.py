import sys
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks.perf_iter import run_variants
run_variants("zamba2-2.7b", "train_4k", [
    {"name": "fulldp_zero_rematfull",
     "hypothesis": ("Iteration 2. After full-DP the bound is memory "
                    "(t_mem 4.45s, temp 125 GiB/dev). remat=full recomputes "
                    "block activations in backward: predict temp ~3x lower, "
                    "t_compute up ~30%."),
     "cfg": {"remat": "full"},
     "rules": {"act_batch": ("data", "model"), "act_inner": None,
               "act_heads": None, "act_kv_heads": None, "act_mlp": None,
               "act_vocab": None, "inner": None, "heads": None,
               "kv_heads": None, "mlp": None, "vocab": None}},
], include_baseline=False)
