import sys
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks.perf_iter import run_variants
from repro.configs.base import MoEConfig

run_variants("zamba2-2.7b", "train_4k", [
    {"name": "fulldp_zero_rematfull_v2",
     "hypothesis": ("Iteration 2-fixed. First attempt was a silent no-op: "
                    "remat was never wired into the hybrid family's forward "
                    "(identical numbers = refuted-by-bug). With "
                    "jax.checkpoint around each group (6 mamba + 1 shared "
                    "block), backward stores only group boundaries: "
                    "predict temp 125 -> ~40-60 GiB, t_memory down, "
                    "t_compute +~30% recompute."),
     "cfg": {"remat": "full"},
     "rules": {"act_batch": ("data", "model"), "act_inner": None,
               "act_heads": None, "act_kv_heads": None, "act_mlp": None,
               "act_vocab": None, "inner": None, "heads": None,
               "kv_heads": None, "mlp": None, "vocab": None}},
], include_baseline=False)

EP = MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25, impl="ep")
run_variants("phi3.5-moe-42b-a6.6b", "train_4k", [
    {"name": "ep_a2a_sp_rematfull",
     "hypothesis": ("Iteration 3. Memory still dominates (4.89s, temp 96 "
                    "GiB). remat=full (vs dots_saveable) trades recompute "
                    "for activation memory: predict temp -> ~50 GiB, "
                    "t_memory -> ~3.5s, t_compute 1.27 -> ~1.7s."),
     "cfg": {"moe": EP, "remat": "full"},
     "rules": {"act_seq": ("model",), "act_embed": None}},
], include_baseline=False)
